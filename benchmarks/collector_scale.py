"""Collector-scaling benchmark: single-device vs mesh-sharded SFPL engine.

Sweeps num_clients x local batch size (i.e. pooled-batch size N*B) and
times one SFPL epoch with

  * ``engine.sfpl_epoch``          — everything on one device;
  * ``engine_dist.sfpl_epoch_sharded`` — clients + pooled batch sharded
    over an 8-way ("data",) host mesh, collector shuffle as explicit
    all_to_all (optionally through the Pallas permute kernel).

Forced host devices stand in for a real accelerator mesh, so *wall-clock
speedups here are not the point* — the benchmark pins down the sweep
harness, verifies both engines agree at every size, and records the
per-size loss deltas + timings that a TPU run would fill in. Each record
also carries per-phase timings (perm build / route-plan build / plan
exchange / server update) so the CPU-harness overhead can be localized;
a phase timer that never fired is a hard error, never a silent zero.

Every config is swept in THREE collector pipelines — ``sync`` (one
blocking exchange per step), ``double_buffered`` (per-flush-group
whole-mesh exchanges overlapping the next group's client forward, the
capacity-safe ``b_g + 1`` buffers), and ``submesh`` (the same streamed
pipeline with each group's exchange a DENSE zero-slack collective
confined to its owning shard slice; recorded only when the layout
qualifies, with ``plan_groups``/``slice_size`` and per-group
``plan_build_g{i}_s`` phases) — and the phases are timed PER PIPELINE
with that pipeline's own exchange machinery (sync: one dense plan
exchange over the pool; double_buffered: the per-group issue/complete
exchanges back to back; submesh: the slice-confined per-group
collectives at the sweep alpha), so the records of a config never share
a phases dict. Each streamed record carries ``overlap_savings``, the
fraction of the sync epoch the streamed epoch saved (negative on this
CPU harness means the pipeline's extra buffer traffic outweighed the
overlap — the gap the sub-mesh record exists to close).

Every config is additionally swept over ``compute_dtype`` in
{float32, bfloat16} (``--compute-dtype both``, the default): the bf16
records run the mixed-precision ``ComputePolicy`` engine (f32 master
params, bf16 client forward and smashed exchange) and every record
carries ``compute_dtype`` plus ``exchange_bytes`` — the wire bytes of
one forward pool exchange from the epoch collector's own
``exchange_bytes`` (plan shapes are dtype-independent, so the bf16
payload is exactly half the f32 payload at a matched config).

The dense f32-compute legs are swept over ``--wire-dtype`` (default
``float32 bfloat16 int8``): each name adds records whose exchange ships
in that wire format (``core.wire`` — quantized wires carry 1 byte/elem
plus 4 scale bytes/row, so the int8 payload lands near a quarter of the
f32 payload at the bench's 512-element rows). Every record carries
``wire_dtype``; the bf16-compute and degraded legs keep the identity
wire ``"float32"`` (ship as computed).

Every config is ALSO swept over ``--drop-clients`` (default ``0 1``):
each ``k > 0`` adds a DEGRADED sync-pipeline record with the last ``k``
clients masked out through the elastic participation path — masked rows
still travel the collector (plan shapes are mask-independent), so
``exchange_bytes`` is unchanged and the record says so out loud; the
degraded quantity is throughput. Every record carries
``participation_rate`` (1.0 on dense records) and a ``degraded`` flag.

Run:  PYTHONPATH=src python benchmarks/collector_scale.py \
          [--epochs 2] [--alpha 0.5] [--out BENCH_collector.json] \
          [--use-kernel] [--compute-dtype {float32,bfloat16,both}] \
          [--drop-clients 0 1] [--wire-dtype float32 bfloat16 int8]
Writes ``BENCH_collector.json`` (list of per-config records).
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.core import round as RD
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

SHARDS = 8


def build(num_clients, batch_size, *, hw=8, width=8, seed=0,
          compute_dtype="float32"):
    from repro.launch.train import make_compute_policy
    cfg = R.ResNetConfig(depth=8, num_classes=num_clients, width=width)
    key = jax.random.PRNGKey(seed)
    tx, ty, _, _ = make_synthetic_cifar(
        key, num_classes=num_clients, train_per_class=2 * batch_size,
        test_per_class=2, hw=hw)
    data = partition_positive_labels(tx, ty, num_clients)
    split = E.make_resnet_split(cfg, policy=make_compute_policy(
        compute_dtype, None))
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st = E.init_dcml_state(key, lambda k: R.init(k, cfg), num_clients,
                           opt, opt)
    return cfg, data, split, opt, st


def time_epochs(step, key, st, epochs):
    # warmup/compile
    st1, l = step(key, st)
    jax.block_until_ready(l)
    t0 = time.time()
    losses = []
    for e in range(epochs):
        key, ke = jax.random.split(key)
        st1, l = step(ke, st1)
        losses.append(np.asarray(l))
    jax.block_until_ready(st1["step"])
    return (time.time() - t0) / epochs, np.concatenate(losses)


class PhaseTimers:
    """Registry of per-phase timings that refuses to emit a record with a
    requested phase missing: a timer that never fired (or measured an
    impossible non-positive duration) raises instead of silently writing
    zeros into BENCH_collector.json."""

    def __init__(self, required):
        self.required = tuple(required)
        self._t = {}

    def time(self, name, fn, *args, reps=40, batches=5):
        """Record the MINIMUM per-call time over ``batches`` timed groups
        of ``reps`` calls — the standard microbenchmark estimator for the
        sub-millisecond phases, where a single scheduler stall in a mean
        would swamp the quantity being measured."""
        out = fn(*args)              # warmup/compile
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(batches):
            t0 = time.perf_counter()   # monotonic: a wall-clock step back
            for _ in range(reps):      # must not fail >0 finalize check
                out = fn(*args)
            jax.block_until_ready(out)
            best = min(best, (time.perf_counter() - t0) / reps)
        self._t[name] = best
        return out

    def finalize(self):
        missing = [n for n in self.required
                   if n not in self._t or not self._t[n] > 0.0]
        if missing:
            raise RuntimeError(
                f"phase timer(s) {missing} never fired (or recorded a "
                f"non-positive duration); refusing to write a record "
                f"with silent zeros — got {self._t}")
        return dict(self._t)


def bench_phases(data_sh, split, opt, st_sh, mesh, num_clients, batch_size,
                 *, use_kernel, alpha, pipeline, wire_dtype="float32"):
    """Per-phase timings of the sharded SFPL step — perm build, route-plan
    build, plan exchange, server update — to localize where the
    wall-clock goes (the CPU-harness overhead recorded in
    BENCH_collector.json). Timed PER PIPELINE with that pipeline's own
    collector strategy: ``sync`` exchanges the whole pool with one dense
    plan exchange, ``double_buffered`` with its capacity-safe
    issue/complete halves (no client compute interleaved — the exchange
    cost alone), ``submesh`` with the dense slice-confined per-group
    exchanges AT THE SWEEP ALPHA (a single global flush has no slice
    structure to measure) plus per-group ``plan_build_g{i}_s`` timings.
    The sync/double_buffered microbenches pin ONE GLOBAL FLUSH so the
    exchange numbers stay comparable across bench alphas and releases;
    the ``alpha`` flush structure shows up in the epoch timings."""
    n_pool = num_clients * batch_size
    xb = jax.lax.dynamic_slice_in_dim(data_sh["x"], 0, batch_size, axis=1)
    A, _ = jax.jit(jax.vmap(
        lambda cp, cs, x: split.client_fwd(cp, cs, x, True, None)))(
        st_sh["cp"], st_sh["cbn"], xb)
    a_pool = A.reshape((n_pool,) + A.shape[2:])
    y_pool = jax.lax.dynamic_slice_in_dim(
        data_sh["y"], 0, batch_size, axis=1).reshape((n_pool,))
    key = jax.random.PRNGKey(2)
    required = ["perm_build_s", "plan_build_s", "exchange_s",
                "server_update_s"]

    if pipeline == "submesh":
        coll = RD.DataMesh(mesh).collector(
            num_clients, alpha=alpha, use_kernel=use_kernel,
            pipeline="double_buffered", submesh=True,
            wire_dtype=wire_dtype)
        n_groups = len(coll.group_bounds(n_pool))
        required += [f"plan_build_g{g}_s" for g in range(n_groups)]
    else:
        # phases microbench: one global flush (see docstring). The
        # double_buffered leg pins submesh OFF — a single global flush
        # qualifies trivially for sub-mesh routing (the slice is the whole
        # mesh), and auto-enabling it here would silently swap the
        # whole-mesh fallback buffers this record exists to measure
        coll = RD.DataMesh(mesh).collector(
            num_clients, alpha=1.0, use_kernel=use_kernel,
            pipeline=pipeline,
            submesh=False if pipeline == "double_buffered" else None,
            wire_dtype=wire_dtype)
    timers = PhaseTimers(required)

    perm_fn = jax.jit(lambda k: coll.make_perm(k, n_pool))
    perm = timers.time("perm_build_s", perm_fn, key)

    prep_fn = jax.jit(lambda p: coll.prepare(p, n_pool))
    prep = timers.time("plan_build_s", prep_fn, perm)

    if pipeline == "submesh":
        # per-group dense plan builds: the cost the sub-mesh path adds
        # over one whole-pool plan (each group's (fwd, bwd) pair alone)
        from repro.core.collector_dist import build_submesh_route_plans
        slices = coll.submesh_slices(n_pool)
        n_shards = SHARDS
        for g, (r0, r1) in enumerate(coll.group_bounds(n_pool)):
            sub = jax.lax.slice_in_dim(perm, r0, r1, axis=0) - r0
            timers.time(
                f"plan_build_g{g}_s",
                jax.jit(lambda s, g=g: build_submesh_route_plans(
                    s, g, n_shards, slices)), sub)

    if pipeline in ("double_buffered", "submesh"):
        # produce_group returns the whole pool in both streamed legs:
        # double_buffered is pinned to one global flush (the group IS the
        # pool) and sub-mesh plans take pool-width rows by contract
        def exchange(a, prep):
            return RD.streamed_shuffle(coll, prep, n_pool, lambda g: a)
    else:
        def exchange(a, prep):
            return coll.permute(a, prep)
    a_shuf = timers.time("exchange_s", jax.jit(exchange), a_pool, prep)
    y_shuf = jax.jit(exchange)(y_pool, prep)

    def server_update(sp, sopt, a, y):
        def srv_loss(sp_):
            loss, (nss, _) = split.server_loss(sp_, st_sh["sbn"], a, y,
                                               True, None)
            return loss, nss
        (loss, _), g_sp = jax.value_and_grad(srv_loss, has_aux=True)(sp)
        sp_new, sopt_new = opt.update(g_sp, sopt, sp, st_sh["step"])
        return loss, sp_new, sopt_new
    timers.time("server_update_s", jax.jit(server_update), st_sh["sp"],
                st_sh["sopt"], a_shuf, y_shuf, reps=4)
    return timers.finalize()


def bench_config(num_clients, batch_size, *, epochs, use_kernel, alpha,
                 compute_dtype="float32", drop_clients=0,
                 wire_dtype="float32"):
    """Both pipeline records for one (clients, batch) config; the
    single-device reference epoch runs ONCE and is shared, so the two
    records carry a consistent baseline — but each pipeline's phases are
    timed with ITS OWN exchange machinery (a shared dict once hid a
    byte-identical-phases bug in BENCH_collector.json).

    ``drop_clients=k`` is the DEGRADED leg: the last ``k`` clients sit
    the epoch out via an elastic participation mask (flush groups keep a
    survivor — ``ensure_group_survivor`` revives, logged). Masked rows
    still TRAVEL the collector (the plan shapes are mask-independent), so
    ``exchange_bytes`` is unchanged — the record logs that explicitly
    instead of silently under-reporting the degraded wire cost; only the
    sync pipeline is swept (the throughput question, not the overlap
    one). Every record carries ``participation_rate`` and ``degraded``,
    plus ``skipped_groups`` (always 0 here: ``ensure_group_survivor``
    keeps at least one client per flush group, so the streamed skip fast
    path — whose skipped groups ``exchange_bytes`` excludes — cannot
    arise in this harness).

    ``wire_dtype`` names the exchange's on-wire format (``core.wire``):
    the epoch and the exchange-phase microbench both run with it, and
    ``exchange_bytes`` counts wire bytes — int8 rows + the 4 scale
    bytes/row sidecar for quantized wires."""
    from repro.core.faults import ensure_group_survivor
    cfg, data, split, opt, st0 = build(num_clients, batch_size,
                                       compute_dtype=compute_dtype)
    st0_host = jax.tree_util.tree_map(np.asarray, st0)
    key = jax.random.PRNGKey(1)

    part = None
    if drop_clients:
        m = np.ones(num_clients, bool)
        m[num_clients - drop_clients:] = False
        m, revived = ensure_group_survivor(m, num_clients, alpha=alpha)
        if revived:
            print(f"N={num_clients:3d} B={batch_size:3d} degraded: revived "
                  f"clients {revived} (flush group needs a survivor)",
                  flush=True)
        part = m
    participation_rate = 1.0 if part is None else float(part.mean())

    # smashed-row geometry of THIS config's policy: the exchange payload
    # is counted in the dtype the activations actually cross the
    # collector in (bf16 halves the f32 bytes at identical plan shapes)
    cp0 = jax.tree_util.tree_map(lambda t: t[0], st0["cp"])
    cs0 = jax.tree_util.tree_map(lambda t: t[0], st0["cbn"])
    a1, _ = split.client_fwd(cp0, cs0, data["x"][0, :batch_size])
    row_elems = int(np.prod(a1.shape[1:]))
    exchange_dtype = a1.dtype

    single = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=num_clients,
        batch_size=batch_size, alpha=alpha,
        participation=None if part is None else jnp.asarray(part)))
    t_single, l_single = time_epochs(single, key, st0, epochs)

    mesh = ED.make_data_mesh(SHARDS)
    data_sh = ED.shard_client_data(data, mesh)

    def fresh_sharded():
        return ED.shard_dcml_state(
            jax.tree_util.tree_map(jnp.asarray, st0_host), mesh)

    from repro.core import collector as C
    from repro.core.collector_dist import submesh_slice_size
    n_pool = num_clients * batch_size
    group_rows = [c * batch_size
                  for c in C.flush_group_sizes(num_clients, alpha)]
    if part is not None:
        pipelines = ["sync"]
    else:
        pipelines = ["sync", "double_buffered"]
        if submesh_slice_size(n_pool, SHARDS, group_rows) is not None:
            pipelines.append("submesh")
        else:
            print(f"N={num_clients:3d} B={batch_size:3d} alpha={alpha}: "
                  f"layout does not qualify for sub-mesh routing — no "
                  f"submesh record", flush=True)

    records = []
    for pipeline in pipelines:
        phases = bench_phases(data_sh, split, opt, fresh_sharded(), mesh,
                              num_clients, batch_size,
                              use_kernel=use_kernel, alpha=alpha,
                              pipeline=pipeline, wire_dtype=wire_dtype)
        # the double_buffered record stays the whole-mesh fallback
        # (submesh=False) so it keeps measuring the b_g + 1 buffers the
        # submesh record is compared against
        pipe_kw = {"sync": dict(collector_pipeline="sync"),
                   "double_buffered": dict(
                       collector_pipeline="double_buffered",
                       collector_submesh=False),
                   "submesh": dict(collector_pipeline="double_buffered",
                                   collector_submesh=True)}[pipeline]
        sharded = ED.make_sfpl_epoch_sharded(
            split, opt, opt, data_sh, mesh=mesh, num_clients=num_clients,
            batch_size=batch_size, use_kernel=use_kernel, alpha=alpha,
            wire_dtype=wire_dtype, **pipe_kw)
        step = (sharded if part is None
                else (lambda k, s: sharded(k, s, participation=part)))
        t_sharded, l_sharded = time_epochs(step, key, fresh_sharded(),
                                           epochs)
        # wire bytes of one forward pool exchange, from the EPOCH
        # collector (sweep alpha, this pipeline's plan shapes) — not the
        # pinned-alpha phases collector above
        epoch_coll = RD.DataMesh(mesh).collector(
            num_clients, alpha=alpha, use_kernel=use_kernel,
            wire_dtype=wire_dtype,
            **{"sync": {},
               "double_buffered": dict(pipeline="double_buffered",
                                       submesh=False),
               "submesh": dict(pipeline="double_buffered",
                               submesh=True)}[pipeline])
        eperm = epoch_coll.make_perm(jax.random.PRNGKey(3), n_pool)
        eprep = epoch_coll.prepare(eperm, n_pool)
        rec = {
            "num_clients": num_clients,
            "batch_size": batch_size,
            "pooled_batch": n_pool,
            "shards": SHARDS,
            "use_kernel": use_kernel,
            "alpha": alpha,
            "pipeline": pipeline,
            "compute_dtype": compute_dtype,
            "wire_dtype": wire_dtype,
            "participation_rate": participation_rate,
            "degraded": bool(part is not None),
            "dropped_clients": int(drop_clients),
            # always 0 here: ensure_group_survivor guarantees every flush
            # group a survivor, so no group's exchange is skipped (the
            # skip-aware exchange_bytes would exclude skipped groups)
            "skipped_groups": 0,
            "exchange_bytes": int(epoch_coll.exchange_bytes(
                eprep, row_elems, exchange_dtype)),
            "epochs": epochs,
            "sec_per_epoch_single": t_single,
            "sec_per_epoch_sharded": t_sharded,
            "speedup": t_single / t_sharded,
            "max_loss_delta": float(np.abs(l_single - l_sharded).max()),
            "phases": phases,
        }
        if pipeline == "submesh":
            rec["plan_groups"] = len(group_rows)
            rec["slice_size"] = submesh_slice_size(n_pool, SHARDS,
                                                   group_rows)
        print(f"N={num_clients:3d} B={batch_size:3d} "
              f"pooled={rec['pooled_batch']:4d} {pipeline:15s} "
              f"{compute_dtype:8s} wire={wire_dtype:11s} "
              f"exch {rec['exchange_bytes']:8d}B  "
              f"single {t_single:.3f}s  sharded {t_sharded:.3f}s  "
              f"dloss {rec['max_loss_delta']:.2e}  "
              f"[perm {phases['perm_build_s']*1e3:.1f}ms | plan "
              f"{phases['plan_build_s']*1e3:.1f}ms | exch "
              f"{phases['exchange_s']*1e3:.1f}ms | srv "
              f"{phases['server_update_s']*1e3:.1f}ms]", flush=True)
        if part is not None:
            print(f"N={num_clients:3d} B={batch_size:3d} degraded "
                  f"({drop_clients} dropped, participation "
                  f"{participation_rate:.2f}): masked rows still travel — "
                  f"exchange_bytes unchanged at "
                  f"{rec['exchange_bytes']}B", flush=True)
        records.append(rec)

    rec_sync = records[0]
    # fraction of the sync sharded epoch each streamed epoch saved
    for rec in records[1:]:
        rec["overlap_savings"] = (
            1.0 - rec["sec_per_epoch_sharded"]
            / rec_sync["sec_per_epoch_sharded"])
        print(f"N={num_clients:3d} B={batch_size:3d} "
              f"{rec['pipeline']} overlap_savings "
              f"{rec['overlap_savings']*100:+.1f}%", flush=True)
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--out", default="BENCH_collector.json")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="flush threshold; < 1 gives the double_buffered "
                         "pipeline multiple groups to overlap")
    ap.add_argument("--clients", type=int, nargs="*", default=[8, 16])
    ap.add_argument("--batches", type=int, nargs="*", default=[8, 16])
    ap.add_argument("--compute-dtype", dest="compute_dtype",
                    default="both",
                    choices=("float32", "bfloat16", "both"),
                    help="sweep the mixed-precision ComputePolicy path "
                         "('both' records f32 AND bf16 legs per config)")
    ap.add_argument("--drop-clients", dest="drop_clients", type=int,
                    nargs="*", default=[0, 1],
                    help="elastic degradation sweep: for each k > 0 add a "
                         "sync-pipeline record with the last k clients "
                         "masked out (masked rows still travel — "
                         "exchange_bytes is unchanged, throughput is the "
                         "degraded quantity)")
    from repro.core.wire import WIRE_DTYPE_NAMES
    ap.add_argument("--wire-dtype", dest="wire_dtypes", nargs="*",
                    default=["float32", "bfloat16", "int8"],
                    choices=WIRE_DTYPE_NAMES,
                    help="wire-format sweep (core.wire): each name adds a "
                         "record leg whose exchange ships in that dtype; "
                         "swept on the dense f32-compute legs (wire "
                         "'float32' = ship as computed, so every "
                         "bf16-compute/degraded record still carries it)")
    args = ap.parse_args()
    dtypes = (("float32", "bfloat16") if args.compute_dtype == "both"
              else (args.compute_dtype,))

    records = []
    for n in args.clients:
        for b in args.batches:
            if n % SHARDS or (n * b // SHARDS) % SHARDS:
                print(f"skip N={n} B={b}: not divisible for {SHARDS}-way "
                      f"balanced exchange", flush=True)
                continue
            try:
                # both pipelines must validate for the chosen alpha
                # (double_buffered is the stricter layout) — skip like the
                # launch drivers degrade, instead of crashing mid-sweep
                # after the single-device leg
                ED.check_sfpl_layout(n, b, SHARDS, alpha=args.alpha,
                                     collector_pipeline="double_buffered")
            except ValueError as e:
                print(f"skip N={n} B={b} alpha={args.alpha}: {e}",
                      flush=True)
                continue
            for cd in dtypes:
                for k in args.drop_clients:
                    # wire sweep on the dense f32-compute legs only: the
                    # quantized-wire question is byte ratio + overhead at
                    # a matched config, not its cross product with the
                    # bf16-compute and degradation axes
                    wires = (args.wire_dtypes
                             if cd == "float32" and k == 0
                             else ["float32"])
                    for w in wires:
                        records.extend(bench_config(
                            n, b, epochs=args.epochs,
                            use_kernel=args.use_kernel, alpha=args.alpha,
                            compute_dtype=cd, drop_clients=k,
                            wire_dtype=w))
    out = {
        "bench": "collector_scale",
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"wrote {args.out} ({len(records)} configs)")


if __name__ == "__main__":
    main()
