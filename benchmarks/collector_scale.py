"""Collector-scaling benchmark: single-device vs mesh-sharded SFPL engine.

Sweeps num_clients x local batch size (i.e. pooled-batch size N*B) and
times one SFPL epoch with

  * ``engine.sfpl_epoch``          — everything on one device;
  * ``engine_dist.sfpl_epoch_sharded`` — clients + pooled batch sharded
    over an 8-way ("data",) host mesh, collector shuffle as explicit
    all_to_all (optionally through the Pallas permute kernel).

Forced host devices stand in for a real accelerator mesh, so *wall-clock
speedups here are not the point* — the benchmark pins down the sweep
harness, verifies both engines agree at every size, and records the
per-size loss deltas + timings that a TPU run would fill in.

Run:  PYTHONPATH=src python benchmarks/collector_scale.py \
          [--epochs 2] [--out BENCH_collector.json] [--use-kernel]
Writes ``BENCH_collector.json`` (list of per-config records).
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

SHARDS = 8


def build(num_clients, batch_size, *, hw=8, width=8, seed=0):
    cfg = R.ResNetConfig(depth=8, num_classes=num_clients, width=width)
    key = jax.random.PRNGKey(seed)
    tx, ty, _, _ = make_synthetic_cifar(
        key, num_classes=num_clients, train_per_class=2 * batch_size,
        test_per_class=2, hw=hw)
    data = partition_positive_labels(tx, ty, num_clients)
    split = E.make_resnet_split(cfg)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st = E.init_dcml_state(key, lambda k: R.init(k, cfg), num_clients,
                           opt, opt)
    return cfg, data, split, opt, st


def time_epochs(step, key, st, epochs):
    # warmup/compile
    st1, l = step(key, st)
    jax.block_until_ready(l)
    t0 = time.time()
    losses = []
    for e in range(epochs):
        key, ke = jax.random.split(key)
        st1, l = step(ke, st1)
        losses.append(np.asarray(l))
    jax.block_until_ready(st1["step"])
    return (time.time() - t0) / epochs, np.concatenate(losses)


def bench_config(num_clients, batch_size, *, epochs, use_kernel):
    cfg, data, split, opt, st0 = build(num_clients, batch_size)
    st0_host = jax.tree_util.tree_map(np.asarray, st0)
    key = jax.random.PRNGKey(1)

    single = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=num_clients,
        batch_size=batch_size))
    t_single, l_single = time_epochs(single, key, st0, epochs)

    mesh = ED.make_data_mesh(SHARDS)
    data_sh = ED.shard_client_data(data, mesh)
    sharded = ED.make_sfpl_epoch_sharded(
        split, opt, opt, data_sh, mesh=mesh, num_clients=num_clients,
        batch_size=batch_size, use_kernel=use_kernel)
    st_sh = ED.shard_dcml_state(
        jax.tree_util.tree_map(jnp.asarray, st0_host), mesh)
    t_sharded, l_sharded = time_epochs(sharded, key, st_sh, epochs)

    rec = {
        "num_clients": num_clients,
        "batch_size": batch_size,
        "pooled_batch": num_clients * batch_size,
        "shards": SHARDS,
        "use_kernel": use_kernel,
        "epochs": epochs,
        "sec_per_epoch_single": t_single,
        "sec_per_epoch_sharded": t_sharded,
        "speedup": t_single / t_sharded,
        "max_loss_delta": float(np.abs(l_single - l_sharded).max()),
    }
    print(f"N={num_clients:3d} B={batch_size:3d} pooled={rec['pooled_batch']:4d}  "
          f"single {t_single:.3f}s  sharded {t_sharded:.3f}s  "
          f"dloss {rec['max_loss_delta']:.2e}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--out", default="BENCH_collector.json")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--clients", type=int, nargs="*", default=[8, 16])
    ap.add_argument("--batches", type=int, nargs="*", default=[8, 16])
    args = ap.parse_args()

    records = []
    for n in args.clients:
        for b in args.batches:
            if n % SHARDS or (n * b // SHARDS) % SHARDS:
                print(f"skip N={n} B={b}: not divisible for {SHARDS}-way "
                      f"balanced exchange", flush=True)
                continue
            records.append(bench_config(n, b, epochs=args.epochs,
                                        use_kernel=args.use_kernel))
    out = {
        "bench": "collector_scale",
        "devices": len(jax.devices()),
        "backend": jax.default_backend(),
        "records": records,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"wrote {args.out} ({len(records)} configs)")


if __name__ == "__main__":
    main()
