"""Shared benchmark harness: tiny-but-faithful SFPL/SFLv2/FL experiment
setup (synthetic CIFAR-like data; paper hyperparameters scaled to CPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import engine as E
from repro.core.evaluate import (
    evaluate_split_iid, evaluate_split_noniid, evaluate_fl)
from repro.data import (
    make_synthetic_cifar, partition_positive_labels, partition_iid)
from repro.models import resnet as R
from repro.optim import sgd_momentum, multistep_lr


def setup(*, num_classes=4, depth=8, width=8, hw=16, per_class=48,
          test_per_class=24, seed=0):
    key = jax.random.PRNGKey(seed)
    cfg = R.ResNetConfig(depth=depth, num_classes=num_classes, width=width)
    tx, ty, ex, ey = make_synthetic_cifar(
        key, num_classes=num_classes, train_per_class=per_class,
        test_per_class=test_per_class, hw=hw)
    split = E.make_resnet_split(cfg)
    return dict(cfg=cfg, split=split, train=(tx, ty), test=(ex, ey),
                V=num_classes, key=key)


def make_opt(lr=0.05, epochs=12, steps_per_epoch=6):
    # paper: SGD momentum 0.9, wd 5e-4, MultiStepLR(gamma) at 60/120/160 of
    # 175 epochs -> same fractions of our budget
    total = epochs * steps_per_epoch
    ms = [int(total * f) for f in (60 / 175, 120 / 175, 160 / 175)]
    return sgd_momentum(multistep_lr(lr, ms, 0.1), momentum=0.9,
                        weight_decay=5e-4)


def run_scheme(env, scheme, *, epochs=6, batch_size=8, bn_mode="cmsd",
               training_iid=False, seed=1):
    """Returns (state, report_fn, seconds_per_epoch)."""
    V, cfg, split = env["V"], env["cfg"], env["split"]
    tx, ty = env["train"]
    if training_iid:
        data = partition_iid(jax.random.PRNGKey(seed), tx, ty, V)
    else:
        data = partition_positive_labels(tx, ty, V)
    n_local = data["x"].shape[1]
    steps_pe = n_local // batch_size
    opt = make_opt(epochs=epochs, steps_per_epoch=steps_pe)

    key = jax.random.PRNGKey(seed)
    if scheme == "fl":
        st = E.init_fl_state(key, lambda k: R.init(k, cfg), V, opt)
        step = jax.jit(lambda k, s: E.fl_epoch(
            k, s, data, split, opt, num_clients=V, batch_size=batch_size))
    elif scheme == "sflv2":
        st = E.init_dcml_state(key, lambda k: R.init(k, cfg), V, opt, opt)
        step = jax.jit(lambda k, s: E.sflv2_epoch(
            k, s, data, split, opt, opt, num_clients=V,
            batch_size=batch_size))
    elif scheme == "sfpl":
        st = E.init_dcml_state(key, lambda k: R.init(k, cfg), V, opt, opt)
        step = jax.jit(lambda k, s: E.sfpl_epoch(
            k, s, data, split, opt, opt, num_clients=V,
            batch_size=batch_size, bn_mode=bn_mode))
    else:
        raise ValueError(scheme)

    # warmup/compile
    st_w, _ = step(key, st)
    t0 = time.time()
    losses = None
    for _ in range(epochs):
        key, ke = jax.random.split(key)
        st, losses = step(ke, st)
    dt = (time.time() - t0) / epochs

    ex, ey = env["test"]
    rmsd = bn_mode == "rmsd"

    def report(testing_iid=True):
        if scheme == "fl":
            return evaluate_fl(st, split, ex, ey, V, rmsd=rmsd)
        if testing_iid:
            return evaluate_split_iid(st, split, ex, ey, V, rmsd=rmsd,
                                      batch=32)
        return evaluate_split_noniid(st, split, ex, ey, V, rmsd=rmsd,
                                     batch=24)

    return st, report, dt, (float(losses.mean()) if losses is not None
                            else None)
