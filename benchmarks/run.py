"""Benchmark suite — one function per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows; derived carries the table's
headline quantity (accuracy, factor, bytes, flops...).

  table1  SFLv2 failure under positive labels (IID vs non-IID)   [Table I]
  table2  communication-size / training-time cost model          [Table II]
  table4  client FLOPs / params at the cut layer                 [Table IV]
  table5  SFPL-vs-SFLv2 improvement factor (+ FL reference)      [Table V]
  table6to8  CMSD vs RMSD across the three scenarios        [Tables VI-VIII]
  fig3    per-label accuracy oscillation under SFLv2             [Fig. 3]
  eq11    weight-divergence statistic                            [Eq. 11]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


ROWS = []


def emit(name, us_per_call, derived):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


# --------------------------------------------------------------------------

def table1_sflv2_failure():
    from benchmarks.common import setup, run_scheme
    env = setup()
    for iid in (True, False):
        _, report, dt, loss = run_scheme(env, "sflv2", epochs=6,
                                         bn_mode="rmsd", training_iid=iid)
        rep = report(testing_iid=True)
        emit(f"table1/sflv2_trainIID={iid}_acc", dt * 1e6,
             f"{rep['accuracy']:.2f}")
    # paper Table I: IID ~ 50-74%, non-IID collapses to chance (10%)


def table2_cost():
    """Cost model of Table II (bytes; q = smashed layer size)."""
    N = 10
    W = 78_042 * 4                 # R8 params (bytes, fp32)
    Wc = 464 * 4                   # client portion
    beta = Wc / W
    X = 50_000                     # dataset size (samples)
    q = 32 * 32 * 16 * 4           # smashed data bytes/sample (w=16)
    fl_per_client = 2 * W
    sfl_per_client = (2 * X / N) * q + 2 * beta * W
    sfl_total = 2 * X * q + 2 * beta * N * W
    emit("table2/fl_comms_per_client_bytes", 0, int(fl_per_client))
    emit("table2/sflv2_comms_per_client_bytes", 0, int(sfl_per_client))
    emit("table2/sfpl_comms_per_client_bytes", 0, int(sfl_per_client))
    emit("table2/sfpl_equals_sflv2", 0, True)
    emit("table2/total_comms_bytes", 0, int(sfl_total))


def table4_flops():
    from repro.models import resnet as R
    from repro.models.common import count_params
    for depth, classes, paper_client_p, paper_flops in [
            (8, 10, 464, 475_136), (32, 10, 464, 475_136),
            (32, 100, 464, 475_136), (56, 100, 464, 475_136)]:
        cfg = R.ResNetConfig(depth=depth, num_classes=classes)
        t0 = time.time()
        p, _ = R.init(jax.random.PRNGKey(0), cfg)
        us = (time.time() - t0) * 1e6
        cp = count_params(p["client"])
        fl = R.client_flops_per_datapoint(cfg)
        ok = (cp == paper_client_p) and (fl == paper_flops)
        emit(f"table4/r{depth}_c{classes}_client_params", us, cp)
        emit(f"table4/r{depth}_c{classes}_client_flops", 0,
             f"{fl} (paper={paper_flops} match={ok})")
        emit(f"table4/r{depth}_c{classes}_server_params", 0,
             count_params(p["server"]))


def table5_improvement():
    from benchmarks.common import setup, run_scheme
    env = setup()
    _, rep_sfpl, dt1, _ = run_scheme(env, "sfpl", epochs=8, bn_mode="cmsd")
    acc_sfpl = rep_sfpl(testing_iid=False)["accuracy"]
    _, rep_sfl, dt2, _ = run_scheme(env, "sflv2", epochs=8, bn_mode="rmsd")
    acc_sfl = rep_sfl(testing_iid=True)["accuracy"]
    _, rep_fl, dt3, _ = run_scheme(env, "fl", epochs=8, bn_mode="rmsd")
    acc_fl = rep_fl()["accuracy"]
    factor = acc_sfpl / max(acc_sfl, 1e-9)
    emit("table5/sfpl_nonIID_cmsd_acc", dt1 * 1e6, f"{acc_sfpl:.2f}")
    emit("table5/sflv2_nonIID_rmsd_acc", dt2 * 1e6, f"{acc_sfl:.2f}")
    emit("table5/fl_nonIID_acc", dt3 * 1e6, f"{acc_fl:.2f}")
    emit("table5/improvement_factor", 0, f"{factor:.2f}")


def table6to8_bn():
    from benchmarks.common import setup, run_scheme
    env = setup()
    scenarios = [  # (training_iid, testing_iid, paper table)
        (True, True, "VI"), (False, True, "VII"), (False, False, "VIII")]
    for train_iid, test_iid, tbl in scenarios:
        accs = {}
        for mode in ("rmsd", "cmsd"):
            _, report, dt, _ = run_scheme(env, "sfpl", epochs=8,
                                          bn_mode=mode,
                                          training_iid=train_iid)
            accs[mode] = report(testing_iid=test_iid)["accuracy"]
            emit(f"table{tbl}/sfpl_{mode}_trainIID={train_iid}_"
                 f"testIID={test_iid}", dt * 1e6, f"{accs[mode]:.2f}")
        winner = max(accs, key=accs.get)
        emit(f"table{tbl}/winner", 0,
             f"{winner} (paper: {'rmsd' if test_iid else 'cmsd'})")


def fig3_forgetting():
    """Per-label accuracy trajectory under SFLv2: accuracy concentrates on
    the last-visited client's label (catastrophic forgetting)."""
    from benchmarks.common import setup, make_opt
    from repro.core import engine as E
    from repro.core.evaluate import evaluate_split_iid
    from repro.models import resnet as R
    from repro.data import partition_positive_labels
    env = setup()
    V, cfg, split = env["V"], env["cfg"], env["split"]
    tx, ty = env["train"]
    ex, ey = env["test"]
    data = partition_positive_labels(tx, ty, V)
    opt = make_opt()
    st = E.init_dcml_state(jax.random.PRNGKey(0),
                           lambda k: R.init(k, cfg), V, opt, opt)
    step = jax.jit(lambda k, s: E.sflv2_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8))
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    per_class_hist = []
    for ep in range(6):
        key, ke = jax.random.split(key)
        st, _ = step(ke, st)
        rep = evaluate_split_iid(st, split, ex, ey, V, rmsd=True, batch=24)
        per_class_hist.append([round(float(a), 2)
                               for a in rep["per_class_acc"]])
    dt = (time.time() - t0) / 6
    for ep, pc in enumerate(per_class_hist):
        emit(f"fig3/epoch{ep}_per_class_acc", dt * 1e6,
             "|".join(map(str, pc)))
    # forgetting signature: per-class accuracy is near-one-hot
    last = jnp.asarray(per_class_hist[-1])
    emit("fig3/max_minus_mean_last_epoch", 0,
         f"{float(last.max() - last.mean()):.2f}")


def eq11_divergence():
    """Weight divergence (Eq. 11): weights trained under non-IID data
    diverge from the IID-trained ("SGD") reference far more for SFLv2 than
    for SFPL. Measured on the server-side model — the portion that holds
    nearly all parameters and absorbs the data-distribution skew (the
    464-param client conv shows no signal at this scale)."""
    from benchmarks.common import setup, run_scheme
    from repro.core.evaluate import weight_divergence
    env = setup()
    st_iid, _, dt, _ = run_scheme(env, "sfpl", epochs=6, training_iid=True)
    w_ref = st_iid["sp"]
    st_sfpl, _, _, _ = run_scheme(env, "sfpl", epochs=6, training_iid=False)
    st_sfl, _, _, _ = run_scheme(env, "sflv2", epochs=6, training_iid=False)
    d_sfpl = float(weight_divergence(st_sfpl["sp"], w_ref))
    d_sfl = float(weight_divergence(st_sfl["sp"], w_ref))
    emit("eq11/server_weight_divergence_sfpl", dt * 1e6, f"{d_sfpl:.4f}")
    emit("eq11/server_weight_divergence_sflv2", 0, f"{d_sfl:.4f}")
    emit("eq11/sflv2_over_sfpl", 0, f"{d_sfl / max(d_sfpl, 1e-9):.2f}")


def kernels_micro():
    """Microbenchmarks of the Pallas kernels in interpret mode (correctness
    path); wall-times are CPU-interpret, not TPU)."""
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.collector_permute.ops import collector_permute
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 128, 4, 64))
    k = jax.random.normal(key, (1, 128, 2, 64))
    out = flash_attention(q, k, k, causal=True, interpret=True)
    t0 = time.time()
    flash_attention(q, k, k, causal=True, interpret=True).block_until_ready()
    emit("kernels/flash_attention_128", (time.time() - t0) * 1e6,
         f"{float(jnp.mean(out)):.5f}")
    x = jax.random.normal(key, (512, 512))
    s = jnp.ones(512)
    rmsnorm(x, s, interpret=True)
    t0 = time.time()
    rmsnorm(x, s, interpret=True).block_until_ready()
    emit("kernels/rmsnorm_512x512", (time.time() - t0) * 1e6, "ok")
    perm = jax.random.permutation(key, 512)
    collector_permute(x, perm, interpret=True)
    t0 = time.time()
    collector_permute(x, perm, interpret=True).block_until_ready()
    emit("kernels/collector_permute_512", (time.time() - t0) * 1e6, "ok")


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    table4_flops()
    table2_cost()
    kernels_micro()
    table1_sflv2_failure()
    table5_improvement()
    table6to8_bn()
    fig3_forgetting()
    eq11_divergence()
    print(f"# total bench time {time.time()-t0:.1f}s ({len(ROWS)} rows)")


if __name__ == "__main__":
    main()
