"""Paper-scale faithful run (CPU-feasible slice of Table V):

10 clients == 10 classes (CIFAR-10 cardinality), 32x32x3 synthetic images,
ResNet-8 width 16 (exact Table-IV client: 464 params / 475.136K flops),
minibatch 4 (paper's setting), SGD momentum 0.9 / wd 5e-4 / MultiStepLR.

Writes paper_scale_results.json for EXPERIMENTS.md §Paper-claims.

Run:  PYTHONPATH=src:. python -m benchmarks.paper_scale [--epochs 20]
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from benchmarks.common import setup, run_scheme


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=18)
    ap.add_argument("--per-class", type=int, default=80)
    ap.add_argument("--depth", type=int, default=8)
    args = ap.parse_args()

    env = setup(num_classes=10, depth=args.depth, width=16, hw=32,
                per_class=args.per_class, test_per_class=40)
    out = {"config": {"classes": 10, "depth": args.depth, "width": 16,
                      "hw": 32, "per_class": args.per_class,
                      "epochs": args.epochs, "batch": 4}}
    t0 = time.time()

    _, rep, dt, _ = run_scheme(env, "sflv2", epochs=args.epochs,
                               batch_size=4, bn_mode="rmsd")
    out["sflv2_rmsd_testIID"] = rep(testing_iid=True)
    out["sflv2_epoch_s"] = dt
    print("sflv2:", out["sflv2_rmsd_testIID"]["accuracy"], flush=True)

    for mode in ("cmsd", "rmsd"):
        _, rep, dt, _ = run_scheme(env, "sfpl", epochs=args.epochs,
                                   batch_size=4, bn_mode=mode)
        out[f"sfpl_{mode}_test_nonIID"] = rep(testing_iid=False)
        out[f"sfpl_{mode}_test_IID"] = rep(testing_iid=True)
        out[f"sfpl_{mode}_epoch_s"] = dt
        print(f"sfpl {mode}: nonIID",
              out[f"sfpl_{mode}_test_nonIID"]["accuracy"],
              "IID", out[f"sfpl_{mode}_test_IID"]["accuracy"], flush=True)

    _, rep, dt, _ = run_scheme(env, "fl", epochs=args.epochs, batch_size=4,
                               bn_mode="rmsd")
    out["fl_testIID"] = rep()
    print("fl:", out["fl_testIID"]["accuracy"], flush=True)

    acc_sfpl = out["sfpl_cmsd_test_nonIID"]["accuracy"]
    acc_sfl = out["sflv2_rmsd_testIID"]["accuracy"]
    out["improvement_factor"] = acc_sfpl / max(acc_sfl, 1e-9)
    out["wall_s"] = time.time() - t0
    for k, v in list(out.items()):
        if isinstance(v, dict) and "per_class_acc" in v:
            v["per_class_acc"] = [round(float(a), 3)
                                  for a in v["per_class_acc"]]
    with open("paper_scale_results.json", "w") as f:
        json.dump(out, f, indent=1, default=float)
    print(f"\nimprovement factor {out['improvement_factor']:.2f}x "
          f"(total {out['wall_s']:.0f}s)")


if __name__ == "__main__":
    main()
