"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
sweep JSONs (dryrun_single.json, dryrun_multi.json, roofline_single.json).

Run:  PYTHONPATH=src:. python -m benchmarks.make_tables > tables.md
"""
from __future__ import annotations

import json


def gib(x):
    return (x or 0) / 2 ** 30


def dryrun_table(single, multi):
    by_key = {(r.get("arch"), r.get("shape")): r for r in multi}
    print("| arch | shape | mesh 16x16: GiB/dev | flops/dev | coll GiB/dev "
          "| mesh 2x16x16: GiB/dev | coll GiB/dev | status |")
    print("|---|---|---|---|---|---|---|---|")
    for r in single:
        key = (r.get("arch"), r.get("shape"))
        m = by_key.get(key, {})
        if "skipped" in r:
            print(f"| {key[0]} | {key[1]} | — | — | — | — | — | "
                  f"SKIP ({r['skipped'][:48]}…) |")
            continue
        if "error" in r:
            print(f"| {key[0]} | {key[1]} | — | — | — | — | — | "
                  f"ERROR {r['error'][:40]} |")
            continue
        mem = r["memory"]
        per = gib((mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0)
                  - (mem.get("alias_bytes") or 0))
        coll = gib(sum(v["traffic_bytes"]
                       for v in r["collectives"].values()))
        if m and "memory" not in m:
            m = {}
        if m:
            mm = m["memory"]
            per2 = gib((mm["argument_bytes"] or 0) + (mm["temp_bytes"] or 0)
                       - (mm.get("alias_bytes") or 0))
            coll2 = gib(sum(v["traffic_bytes"]
                            for v in m["collectives"].values()))
            m_s = f"{per2:.2f} | {coll2:.2f}"
        else:
            m_s = "— | —"
        print(f"| {key[0]} | {key[1]} | {per:.2f} | "
              f"{r['cost']['flops'] or 0:.2e} | {coll:.2f} | {m_s} | "
              f"compiled ✓✓ |")


def roofline_table(roof):
    print()
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL/HLO flops | note |")
    print("|---|---|---|---|---|---|---|---|")
    for r in roof:
        if "skipped" in r:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                  f"skipped |")
            continue
        if "error" in r:
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                  f"ERROR |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
              f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
              f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
              f"{r['recommendation'][:46]}… |")


def main():
    single = json.load(open("dryrun_single.json"))
    multi = json.load(open("dryrun_multi.json"))
    print("### Dry-run matrix (memory from the scanned deployment config)\n")
    dryrun_table(single, multi)
    try:
        roof = json.load(open("roofline_single.json"))
        print("\n### Roofline (single-pod, 256 chips; per train/serve step)\n")
        roofline_table(roof)
    except FileNotFoundError:
        print("\n(roofline_single.json not ready)")


if __name__ == "__main__":
    main()
