"""Pytree checkpointing to .npz (no orbax in container).

Leaves are flattened to ``path -> array`` with '/'-joined dict keys; restore
rebuilds into the reference tree's structure (shape verified, dtype re-cast
to the reference leaf — bf16 round-trips through exact f32 widening).

Writes are crash-safe: the archive is written to a sibling temp file
through an open handle (so numpy cannot append its own ``.npz`` suffix),
fsync'd, and atomically ``os.replace``d over the target — a reader either
sees the old complete checkpoint or the new complete checkpoint, never a
torn one.

On multi-host meshes some leaves are jax Arrays that are not fully
addressable from any single process; ``_to_host`` pulls a replicated
leaf's local shard and allgathers a sharded leaf.  The allgather is a
COLLECTIVE: every process must call :func:`save_checkpoint` (or
:func:`save_train_state`) at the same point, while only the elected
writer (process 0 by default) touches the filesystem.

:func:`save_train_state` / :func:`restore_train_state` extend the plain
pytree snapshot to the full training state needed for bit-compatible
resume: params + optimizer state + BN statistics (the ``st`` dict), the
host PRNG key, and the epoch counter.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _to_host(leaf):
    """Fetch a leaf to host memory, including non-addressable mesh arrays."""
    try:
        return np.asarray(leaf)
    except RuntimeError:
        # Multi-host jax.Array: no single process sees every shard.
        if getattr(leaf, "is_fully_replicated", False):
            return np.asarray(leaf.addressable_data(0))
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(leaf, tiled=True))


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = _to_host(leaf)
        if arr.dtype == jnp.bfloat16:   # npz can't serialize ml_dtypes;
            arr = arr.astype(np.float32)  # exact widening, re-cast on load
        flat[key] = arr
    return flat


def save_checkpoint(path, tree, *, step=None, write=True):
    """Snapshot ``tree`` to ``path`` atomically.

    ``write=False`` performs the (possibly collective) host fetch but skips
    the file I/O — multi-host callers invoke this on every process and
    elect one writer.
    """
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    if not write:
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        # An open handle pins the destination name: np.savez appends
        # ".npz" to bare paths but writes file objects verbatim.
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def restore_checkpoint(path, ref_tree):
    """Restore into ``ref_tree``'s structure. Returns (tree, step|None).

    Raises ``ValueError`` (not ``assert`` — asserts vanish under
    ``python -O``) on a missing leaf or a shape mismatch against the
    reference tree; dtypes are re-cast to the reference leaf's dtype.
    """
    with np.load(path) as data:
        step = data["__step__"] if "__step__" in data.files else None
        leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(ref_tree)
        out = []
        for pathk, ref in leaves_ref:
            key = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in pathk)
            if key not in data.files:
                raise ValueError(
                    f"checkpoint {path!r} has no leaf {key!r} — reference "
                    f"tree does not match the saved structure")
            arr = data[key]
            ref_shape = tuple(getattr(ref, "shape", np.shape(ref)))
            if tuple(arr.shape) != ref_shape:
                raise ValueError(
                    f"checkpoint {path!r} leaf {key!r} has shape "
                    f"{tuple(arr.shape)}, reference expects {ref_shape}")
            out.append(jnp.asarray(arr, dtype=ref.dtype))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(ref_tree), out)
    return tree, (int(step) if step is not None else None)


# --------------------------------------------------------------------------
# Full training state (params + opt state + BN stats + PRNG key + epoch)


def save_train_state(path, st, *, key, epoch, write=None):
    """Snapshot the full training state for mid-training resume.

    ``st`` is the DCML state dict (client/server params, optimizer states,
    BN statistics, step counter), ``key`` the host-side PRNG key that
    seeds the NEXT epoch, ``epoch`` the number of epochs already finished.
    Every process of a multi-host run must call this (the host fetch can
    allgather); by default only process 0 writes.
    """
    if write is None:
        write = jax.process_index() == 0
    save_checkpoint(path, {"st": st, "key": key}, step=epoch, write=write)


def restore_train_state(path, st_ref, *, key_ref=None):
    """Returns ``(st, key, epoch)`` restored against reference structures.

    ``st_ref`` supplies tree structure/shapes/dtypes (a freshly initialized
    state works); ``key_ref`` defaults to a standard PRNG key.
    """
    if key_ref is None:
        key_ref = jax.random.PRNGKey(0)
    tree, epoch = restore_checkpoint(path, {"st": st_ref, "key": key_ref})
    if epoch is None:
        raise ValueError(
            f"checkpoint {path!r} has no epoch counter (__step__) — was it "
            f"written by save_train_state?")
    return tree["st"], tree["key"], epoch
