"""Pytree checkpointing to .npz (no orbax in container).

Leaves are flattened to ``path -> array`` with '/'-joined dict keys; restore
rebuilds into the reference tree's structure (shape/dtype verified).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:   # npz can't serialize ml_dtypes;
            arr = arr.astype(np.float32)  # exact widening, re-cast on load
        flat[key] = arr
    return flat


def save_checkpoint(path, tree, *, step=None):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)


def restore_checkpoint(path, ref_tree):
    """Restore into ``ref_tree``'s structure. Returns (tree, step|None)."""
    with np.load(path) as data:
        step = data["__step__"] if "__step__" in data.files else None
        leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(ref_tree)
        out = []
        for pathk, ref in leaves_ref:
            key = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in pathk)
            arr = data[key]
            assert arr.shape == ref.shape, (key, arr.shape, ref.shape)
            out.append(jnp.asarray(arr, dtype=ref.dtype))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(ref_tree), out)
    return tree, (int(step) if step is not None else None)
