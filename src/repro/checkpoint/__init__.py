from repro.checkpoint.npz import save_checkpoint, restore_checkpoint
