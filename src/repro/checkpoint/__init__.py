from repro.checkpoint.npz import (save_checkpoint, restore_checkpoint,
                                  save_train_state, restore_train_state)
