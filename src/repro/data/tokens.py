"""Synthetic LM token streams for the assigned-architecture smoke tests and
the e2e LM training example (a learnable k-th order Markov source)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def synthetic_token_stream(key, *, batch, seq_len, vocab, order=2):
    """Deterministic-ish Markov chain: next = (a*prev + b*prev2 + c) % vocab
    with per-stream offsets; learnable by any LM. Returns tokens, labels."""
    k1, k2 = jax.random.split(key)
    x0 = jax.random.randint(k1, (batch, order), 0, vocab)
    offset = jax.random.randint(k2, (batch, 1), 0, vocab)

    def step(carry, _):
        prev = carry
        nxt = (3 * prev[:, -1] + 5 * prev[:, -2] + offset[:, 0] + 7) % vocab
        carry = jnp.concatenate([prev[:, 1:], nxt[:, None]], axis=1)
        return carry, nxt

    _, toks = jax.lax.scan(step, x0, None, length=seq_len + 1)
    toks = toks.T                                  # (B, S+1)
    return toks[:, :-1].astype(jnp.int32), toks[:, 1:].astype(jnp.int32)
