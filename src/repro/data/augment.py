"""Training-time augmentations used by the paper (flip, crop, normalize,
small rotation via 90-degree-free shear substitute is skipped: the paper's
rotation is mild and our synthetic set doesn't need it)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def augment_batch(key, x, *, pad=4):
    """Random horizontal flip + random crop with reflection padding.
    x: (B, H, W, C)."""
    B, H, W, C = x.shape
    kf, kc = jax.random.split(key)
    flip = jax.random.bernoulli(kf, 0.5, (B,))
    x = jnp.where(flip[:, None, None, None], x[:, :, ::-1, :], x)
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                 mode="reflect")
    offs = jax.random.randint(kc, (B, 2), 0, 2 * pad + 1)

    def crop_one(img, o):
        return jax.lax.dynamic_slice(img, (o[0], o[1], 0), (H, W, C))

    return jax.vmap(crop_one)(xp, offs)


def normalize(x, mean, std):
    return (x - jnp.asarray(mean)) / jnp.asarray(std)
