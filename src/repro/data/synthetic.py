"""Synthetic class-conditional image dataset + the paper's partitioners.

CIFAR-10/100 are not downloadable in this offline container (DESIGN.md §2),
so the paper's *phenomena* are reproduced on a learnable synthetic set:
each class v gets a smooth random template T_v (low-frequency, CIFAR-like
statistics); samples are T_v + structured noise + random shift. A centralized
model reaches high accuracy quickly, which is exactly what's needed to
expose the SFLv2-vs-SFPL gap under positive-only labels.

Partitioners implement the paper's two regimes:
  * ``partition_positive_labels`` — client k receives ONLY class k
    (extreme non-IID, |clients| == |classes|)
  * ``partition_iid``             — shuffled equal shards
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _smooth(key, shape, cutoff=6):
    """Low-frequency random image: random coarse grid, bilinear-upsampled."""
    h, w, c = shape
    coarse = jax.random.normal(key, (cutoff, cutoff, c))
    img = jax.image.resize(coarse, (h, w, c), method="bilinear")
    return img / (jnp.std(img) + 1e-6)


def make_synthetic_cifar(key, *, num_classes=10, train_per_class=200,
                         test_per_class=50, hw=32, noise=0.35):
    """Returns (train_x, train_y, test_x, test_y), images (N, hw, hw, 3)."""
    kt, kn = jax.random.split(key)
    templates = jnp.stack([
        _smooth(jax.random.fold_in(kt, v), (hw, hw, 3))
        for v in range(num_classes)])                  # (V, hw, hw, 3)

    def gen(key, per_class):
        n = num_classes * per_class
        y = jnp.repeat(jnp.arange(num_classes), per_class)
        k1, k2, k3 = jax.random.split(key, 3)
        eps = jax.random.normal(k1, (n, hw, hw, 3)) * noise
        # per-sample smooth distractor (shared across classes) + shifts
        amp = jax.random.uniform(k2, (n, 1, 1, 1), minval=0.2, maxval=0.6)
        max_roll = max(1, hw // 10)   # shift scales with image size
        rolls = jax.random.randint(k3, (n, 2), -max_roll, max_roll + 1)
        base = templates[y]
        distract = jnp.roll(base, 1, axis=1) * 0.0
        x = base + eps + distract * amp

        def roll_one(img, r):
            return jnp.roll(jnp.roll(img, r[0], axis=0), r[1], axis=1)
        x = jax.vmap(roll_one)(x, rolls)
        return x.astype(jnp.float32), y.astype(jnp.int32)

    k1, k2 = jax.random.split(kn)
    train_x, train_y = gen(k1, train_per_class)
    test_x, test_y = gen(k2, test_per_class)
    return train_x, train_y, test_x, test_y


def partition_positive_labels(x, y, num_classes):
    """Client k gets exactly class k. Returns {"x": (N, n, ...), "y": ...}."""
    xs, ys = [], []
    n_min = min(int(jnp.sum(y == k)) for k in range(num_classes))
    for k in range(num_classes):
        idx = jnp.where(y == k, size=n_min)[0]
        xs.append(x[idx])
        ys.append(y[idx])
    return {"x": jnp.stack(xs), "y": jnp.stack(ys)}


def partition_iid(key, x, y, num_clients):
    """Shuffle then split into equal shards (the paper's IID control)."""
    n = x.shape[0]
    per = n // num_clients
    perm = jax.random.permutation(key, n)[:per * num_clients]
    xs = x[perm].reshape(num_clients, per, *x.shape[1:])
    ys = y[perm].reshape(num_clients, per)
    return {"x": xs, "y": ys}
