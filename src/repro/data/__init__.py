from repro.data.synthetic import (
    make_synthetic_cifar, partition_positive_labels, partition_iid)
from repro.data.augment import augment_batch
from repro.data.tokens import synthetic_token_stream
