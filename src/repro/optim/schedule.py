"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def multistep_lr(base_lr, milestones, gamma):
    """Paper setup: MultiStepLR, milestones in *steps* (convert epochs
    upstream), multiplicative ``gamma`` at each milestone."""
    ms = jnp.asarray(sorted(milestones), jnp.int32)

    def fn(step):
        n = jnp.sum(step >= ms)
        return jnp.asarray(base_lr, jnp.float32) * (gamma ** n)

    return fn


def cosine_lr(base_lr, total_steps, *, warmup=0, min_ratio=0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(
            total_steps - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return base_lr * warm * cos

    return fn
