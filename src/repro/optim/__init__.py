from repro.optim.sgd import sgd_momentum, adamw
from repro.optim.schedule import multistep_lr, constant_lr, cosine_lr
