"""Optimizers (optax-style (init, update) pairs; no optax in container).

The paper trains with SGD: lr 1e-1, momentum 0.9, weight decay 5e-4,
MultiStepLR decay (gamma 2e-2 at epochs 60/120/160). SGD-momentum is also
the default for giant-arch dry-runs (one state tensor — the memory-frugal
choice the paper's IoT setting implies). AdamW is provided for LM training.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable     # (grads, opt_state, params, step) -> (new_p, new_s)


def sgd_momentum(lr, *, momentum=0.9, weight_decay=0.0, nesterov=False,
                 state_dtype=None):
    """lr: float or schedule fn(step) -> float."""
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(
                p, dtype=state_dtype or p.dtype), params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)

        def upd(g, mu, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            mu_new = momentum * mu.astype(jnp.float32) + g
            d = (g + momentum * mu_new) if nesterov else mu_new
            p_new = p.astype(jnp.float32) - lr_t * d
            return p_new.astype(p.dtype), mu_new.astype(mu.dtype)

        out = jax.tree_util.tree_map(upd, grads, state["mu"], params)
        new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"mu": new_mu}

    return Optimizer(init, update)


def adamw(lr, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
          state_dtype=None):
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=state_dtype or jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m_new / (1 - b1 ** t)
            vhat = v_new / (1 - b2 ** t)
            d = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * d
            return p_new.astype(p.dtype), m_new.astype(m.dtype), \
                v_new.astype(v.dtype)

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

    return Optimizer(init, update)
