import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

# Roofline runner: per (arch x shape), lower unrolled 1-unit and 2-unit
# variants, extrapolate, and emit the §Roofline table rows as JSON + md.
#
# Usage:
#   PYTHONPATH=src python -m repro.roofline.run --all --out roofline.json
#   PYTHONPATH=src python -m repro.roofline.run --arch qwen3-8b --shape train_4k

import argparse
import json
import traceback

from repro.configs import get_arch, list_archs, SHAPES
from repro.launch.dryrun import lower_one
from repro.roofline.analysis import (
    _family_units, roofline_terms, RECOMMENDATIONS)


def roofline_pair(arch_id, shape_name, *, multi_pod=False, sfpl=False,
                  cfg_overrides=None, fsdp=True):
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    skip = spec.skip_reason(shape)
    if skip:
        return {"arch": arch_id, "shape": shape_name, "skipped": skip}
    cfg_full = spec.make_config(**(cfg_overrides or {}))
    n_units, ov1, ov2 = _family_units(spec, cfg_full)
    base_ov = dict(cfg_overrides or {}, scan_layers=False)
    r1 = lower_one(arch_id, shape_name, multi_pod=multi_pod, sfpl=sfpl,
                   cfg_overrides=dict(base_ov, **ov1), fsdp=fsdp)
    r2 = lower_one(arch_id, shape_name, multi_pod=multi_pod, sfpl=sfpl,
                   cfg_overrides=dict(base_ov, **ov2), fsdp=fsdp)
    devices = r1["devices"]
    terms = roofline_terms(r1, r2, n_units, devices=devices, shape=shape,
                           spec=spec, cfg=cfg_full)
    out = {
        "arch": arch_id, "shape": shape_name,
        "mesh": r1["mesh"], "devices": devices, "sfpl": sfpl,
        "num_units": n_units,
        **{k: v for k, v in terms.items() if k != "coll_breakdown"},
        "coll_breakdown": terms["coll_breakdown"],
        "recommendation": RECOMMENDATIONS[terms["dominant"]],
    }
    return out


def row_md(r):
    if "skipped" in r:
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped: "
                f"{r['skipped'][:40]}… | |")
    return ("| {arch} | {shape} | {c:.2e} | {m:.2e} | {l:.2e} | "
            "**{dom}** | {ratio:.2f} | {rec} |").format(
        arch=r["arch"], shape=r["shape"], c=r["compute_s"],
        m=r["memory_s"], l=r["collective_s"], dom=r["dominant"],
        ratio=r["useful_ratio"], rec=r["recommendation"][:60])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sfpl", action="store_true")
    ap.add_argument("--out", default="roofline.json")
    args = ap.parse_args()

    jobs = ([(a, s) for a in list_archs() for s in SHAPES]
            if args.all else [(args.arch, args.shape)])
    results = []
    for a, s in jobs:
        try:
            r = roofline_pair(a, s, sfpl=args.sfpl)
        except Exception as e:
            r = {"arch": a, "shape": s,
                 "error": f"{type(e).__name__}: {e}",
                 "traceback": traceback.format_exc()[-1500:]}
            print(f"FAIL {a} {s}: {e}", flush=True)
        results.append(r)
        if "error" not in r:
            print(row_md(r), flush=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
