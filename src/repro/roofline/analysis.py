"""Three-term roofline from the compiled dry-run.

Method. ``cost_analysis()`` counts a ``lax.scan`` body ONCE, not x trip
count, so scanning the layer stack (the deployment config) under-reports
FLOPs/bytes/collectives. We therefore lower each (arch, shape) twice with
the layer scan UNROLLED at 1 and 2 layer-units and extrapolate linearly:

    total = cost(1u) + (cost(2u) - cost(1u)) * (num_units - 1)

which captures the per-unit cost exactly (including per-layer weight
all-gathers) plus the base cost (embedding, unembedding/loss, collectives
outside the stack). One residual undercount remains: the kv-chunk scan
inside long-sequence attention (prefill_32k) — corrected analytically with
the closed-form attention FLOP count.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_traffic_per_device / ICI_link_bw
"""
from __future__ import annotations

import math

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # bytes/s
    "ici_bw": 50e9,         # bytes/s per link
}


def _family_units(spec, cfg):
    """(num_units, base_overrides, double_overrides) for the extrapolation."""
    fam = spec.family
    if fam == "transformer":
        G = cfg.group_size
        n = cfg.num_layers // G
        return n, {"num_layers": G}, {"num_layers": 2 * G}
    if fam == "xlstm":
        G = cfg.slstm_every
        n = cfg.num_layers // G
        return n, {"num_layers": G}, {"num_layers": 2 * G}
    if fam == "rglru":
        pat = len(cfg.pattern)
        trail = cfg.num_layers % pat
        n = cfg.num_layers // pat
        return n, {"num_layers": pat + trail}, {"num_layers": 2 * pat + trail}
    if fam == "whisper":
        return cfg.num_layers, {"num_layers": 1}, {"num_layers": 2}
    raise ValueError(fam)


def active_params(spec, cfg):
    """Active parameter count (MoE: 1-of-E routed + shared + dense)."""
    import jax
    shapes = jax.eval_shape(
        lambda: spec.model.init(jax.random.PRNGKey(0), cfg))
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        names = [str(getattr(k, "key", k)) for k in path]
        n = math.prod(leaf.shape)
        if "moe" in names and names[names.index("moe") + 1] in \
                ("wi", "wg", "wo"):
            n = n / cfg.num_experts     # top-1: one expert active
        total += n
    return total


def attention_flops_global(spec, cfg, shape):
    """Closed-form attention FLOPs (fwd; x3 for training) across layers."""
    B, S = shape.global_batch, shape.seq_len
    if spec.family == "xlstm":
        return 0.0   # mLSTM chunk form counted via unroll delta
    if spec.family == "whisper":
        St = min(448, S)
        enc = 4 * B * cfg.num_heads * cfg.head_dim * S * S
        dec = 4 * B * cfg.num_heads * cfg.head_dim * (0.5 * St * St + St * S)
        return cfg.num_layers * (enc + dec)
    H, D = cfg.num_heads, cfg.head_dim
    total = 0.0
    num_layers = cfg.num_layers
    for idx in range(num_layers):
        if spec.family == "rglru":
            pat = cfg.pattern[idx % len(cfg.pattern)] \
                if idx < (cfg.num_layers // len(cfg.pattern)) * len(cfg.pattern) \
                else cfg.pattern[:cfg.num_trailing][idx % len(cfg.pattern)]
            if pat != "attn":
                continue
            window = cfg.window
        else:
            kind = cfg.layer_kind(idx % cfg.group_size)
            window = kind["window"]
        if shape.kind == "decode":
            kv = min(S, window or S)
            total += 4 * B * H * D * kv          # one new token
            continue
        if window is None:
            eff = 0.5 * S * S                    # causal
        else:
            w = min(window, S)
            eff = w * S - 0.5 * w * w            # causal + window
        total += 4 * B * H * D * eff
    if shape.kind == "train":
        total *= 3.0                             # fwd + 2x bwd
    return total


def roofline_terms(base, double, num_units, *, devices, shape, spec, cfg,
                   scan_attn_corrected=True):
    """base/double: result dicts from dryrun.lower_one (unrolled units)."""
    def lin(f1, f2):
        return f1 + (f2 - f1) * (num_units - 1)

    flops = lin(base["cost"]["flops"] or 0, double["cost"]["flops"] or 0)
    bytes_ = lin(base["cost"]["bytes_accessed"] or 0,
                 double["cost"]["bytes_accessed"] or 0)

    coll = {}
    keys = set(base["collectives"]) | set(double["collectives"])
    for k in keys:
        b = base["collectives"].get(k, {"traffic_bytes": 0, "count": 0})
        d = double["collectives"].get(k, {"traffic_bytes": 0, "count": 0})
        coll[k] = lin(b["traffic_bytes"], d["traffic_bytes"])
    coll_bytes = sum(coll.values())

    # analytic correction for the kv-chunk inner scan (prefill long-seq)
    attn_corr = 0.0
    if scan_attn_corrected and shape.seq_len > 2 * 4096 \
            and shape.kind != "decode":
        nck = shape.seq_len / 4096
        full = attention_flops_global(spec, cfg, shape)
        attn_corr = full * (1 - 1.0 / nck) / devices
        flops += attn_corr

    terms = {
        "flops_per_dev": flops,
        "bytes_per_dev": bytes_,
        "coll_bytes_per_dev": coll_bytes,
        "coll_breakdown": coll,
        "attn_flops_correction": attn_corr,
        "compute_s": flops / HW["peak_flops"],
        "memory_s": bytes_ / HW["hbm_bw"],
        "collective_s": coll_bytes / HW["ici_bw"],
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom.replace("_s", "")

    # MODEL_FLOPS = 6 N_active D (train) / 2 N D (inference fwd)
    n_act = active_params(spec, cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mf_coef = 6.0 if shape.kind == "train" else 2.0
    model_flops = mf_coef * n_act * tokens
    terms["model_flops_global"] = model_flops
    terms["hlo_flops_global"] = flops * devices
    terms["useful_ratio"] = (model_flops / max(terms["hlo_flops_global"], 1)
                             if flops else 0.0)
    return terms


RECOMMENDATIONS = {
    "compute": ("compute-bound: raise MFU via larger per-chip batch, "
                "Pallas flash attention on real HW, fused MoE kernels"),
    "memory": ("HBM-bound: fuse norms/elementwise (rmsnorm kernel), cast "
               "saved activations to bf16, widen arithmetic intensity via "
               "bigger tiles"),
    "collective": ("ICI-bound: reduce weight all-gathers (bigger FSDP "
                   "shards/replicate small layers), overlap collectives "
                   "with compute, move batch off the bottleneck axis"),
}
