"""HLO-text parsing: collective traffic extraction for the roofline.

``cost_analysis()`` does not expose collective bytes, so we parse the
compiled module text and sum the result-shape sizes of every collective op.

Traffic model per op type (ring algorithms, n = participants; we report the
result-bytes and a traffic multiplier):
  all-gather         result is the gathered buffer; traffic/device ~ (n-1)/n
                     of result  -> factor 1.0 (upper bound)
  all-reduce         ~2x the buffer (reduce-scatter + all-gather phases)
  reduce-scatter     traffic ~ input ~ result * n ... we only see the result;
                     factor n/(n-1) ~ 1.0 of the *input*; we use result*1.0
                     (lower bound, flagged in EXPERIMENTS.md)
  all-to-all         each device sends (n-1)/n of its shard -> factor 1.0
  collective-permute ~1.0
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# e.g.:  %all-gather.3 = bf16[4,512,1024]{2,1,0} all-gather(...)
# also tuple-shaped: (bf16[...], bf16[...]) all-reduce(...)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _line_result_bytes(line):
    # everything between '=' and the op name is the result shape(s)
    lhs = line.split("=", 1)[1]
    op_pos = len(lhs)
    m = re.search(
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start|-done)?\(", lhs)
    if m:
        op_pos = m.start()
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs[:op_pos]):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_text(hlo_text):
    """Returns {op_type: {"count": int, "bytes": int, "traffic_bytes": int}}.

    ``bytes`` is the summed result-shape size (per device, since the module
    is the SPMD-partitioned per-device program); ``traffic_bytes`` applies
    the per-op traffic factor.
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            # handle "-done" lines? bytes counted at -start only
            continue
        if "-done(" in line:
            continue
        op = m.group(1)
        b = _line_result_bytes(line)
        d = out.setdefault(op, {"count": 0, "bytes": 0, "traffic_bytes": 0})
        d["count"] += 1
        d["bytes"] += b
        d["traffic_bytes"] += int(b * _COLL_FACTOR[op])
    return out
