"""Batch-normalization aggregation policies (the second half of SFPL).

Paper §V-C / Tables VI-VIII: aggregating BN parameters/statistics across
clients with non-IID data hurts. SFPL's ClientFedServer averages the
client-side model *excluding BatchNorm layers* (each client keeps its local
BN); at inference either the aggregated running statistics (RMSD) or the
test batch's own statistics (CMSD) are used.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _path_names(path):
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return names


def is_bn_path(path) -> bool:
    """True if the param path belongs to a BatchNorm layer (keys 'bn*')."""
    return any(n.startswith("bn") for n in _path_names(path))


def fedavg(stacked_params, *, weights=None, exclude_bn=False):
    """FedAvg over the leading client axis of every leaf.

    ``exclude_bn=True`` (SFPL): BN leaves are returned *unchanged* (still
    per-client, leading axis N) while all other leaves are averaged and
    broadcast back to every client — Algorithm 2's ClientFedServer.
    Returns a tree with the same (N, ...) leaf shapes.
    """
    def agg(path, x):
        if exclude_bn and is_bn_path(path):
            return x
        if weights is None:
            avg = jnp.mean(x, axis=0)
        else:
            w = weights / jnp.sum(weights)
            avg = jnp.tensordot(w, x, axes=1)
        return jnp.broadcast_to(avg[None], x.shape)

    return jax.tree_util.tree_map_with_path(agg, stacked_params)


def aggregate_bn_state(stacked_state, *, aggregate=False, weights=None):
    """BN running statistics. SFLv2 (RMSD) aggregates them like params;
    SFPL keeps them local. Returns (N, ...) leaves either way.

    ``weights`` (elastic participation) restricts the aggregate to the
    surviving clients — matching :func:`fedavg`'s weighted mean — so an
    absent client's stale statistics don't drag the pooled RMSD."""
    if not aggregate:
        return stacked_state

    def agg(x):
        if weights is None:
            avg = jnp.mean(x, axis=0)
        else:
            w = weights / jnp.sum(weights)
            avg = jnp.tensordot(w, x, axes=1)
        return jnp.broadcast_to(avg[None], x.shape)

    return jax.tree_util.tree_map(agg, stacked_state)
