"""SFPL core: the paper's contribution as composable JAX modules."""
from repro.core import (collector, bn_policy, engine, evaluate, round,
                        split_lm)
