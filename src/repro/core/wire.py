"""Wire formats for the smashed-data exchange.

The collector's hot cost on constrained links is wire bytes, not FLOPs:
the ``wire_dtype`` axis lets the smashed rows (and, behind the separate
``wire_dtype_bwd`` knob, the routed-back gradient rows) cross each
collective in a narrower dtype than they are computed in, independently
of the f32 master-param training contract.

Supported wire dtypes:

  * ``"float32"``  — the identity wire (ship as computed);
  * ``"bfloat16"`` — cast-only, half the f32 bytes, no sidecar;
  * ``"int8"``     — per-row symmetric amax quantization (qmax 127);
  * ``"float8_e4m3"`` — per-row amax scaling into the e4m3 grid
    (qmax 448).

Quantized wires carry one f32 scale PER ROW. The scale never travels as
a second collective: :func:`pack_scales` bitcasts it into
``SCALE_LANES`` one-byte lanes appended as extra feature columns of the
single payload operand, so the exchange stays one ``all_to_all`` per
direction with the operand in the wire dtype (``SCALE_BYTES`` extra
bytes per row — exact accounting in
``collector_dist.plan_payload_bytes``). A zero payload row (the slack
pad row) unpacks to scale ``0.0`` and dequantizes to exact zeros.

Quantization is per-row symmetric: ``scale = amax / qmax`` with the
``amax == 0`` row mapped to scale 0 (all-zero rows survive the round
trip exactly). Dequantized values satisfy
``|x - dq(q(x))| <= amax / qmax / 2`` for int8 (round-to-nearest on a
127-step grid) and the e4m3 relative error for fp8.

>>> import jax.numpy as jnp
>>> x = jnp.array([[1.0, -2.0, 0.5], [0.0, 0.0, 0.0]])
>>> q, s = quantize_rows(x, "int8")
>>> (q.dtype.name, s.dtype.name, s.shape)
('int8', 'float32', (2,))
>>> (int(q[0, 1]), float(s[1]))
(-127, 0.0)
>>> y = dequantize_rows(q, s, jnp.float32)
>>> bool(jnp.all(y[1] == 0)), bool(jnp.max(jnp.abs(y - x)) < 0.01)
(True, True)
>>> lanes = pack_scales(s, "int8")
>>> (lanes.shape, lanes.dtype.name)
((2, 4), 'int8')
>>> bool(jnp.all(unpack_scales(lanes) == s))
True
>>> is_quantized("bfloat16"), is_quantized("float8_e4m3")
(False, True)
>>> resolve_wire_dtype(None), resolve_wire_dtype("float32")
(None, None)
>>> resolve_wire_dtype("fp4")
Traceback (most recent call last):
    ...
ValueError: unknown wire_dtype 'fp4': expected one of ('float32', \
'bfloat16', 'int8', 'float8_e4m3')
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WIRE_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "int8": jnp.int8,
    "float8_e4m3": jnp.float8_e4m3fn,
}

WIRE_DTYPE_NAMES = tuple(WIRE_DTYPES)

# largest exactly-representable magnitude of each quantized wire grid
QMAX = {"int8": 127.0, "float8_e4m3": 448.0}

# one f32 row scale bitcast into this many one-byte wire lanes
SCALE_LANES = 4
SCALE_BYTES = 4


def resolve_wire_dtype(name):
    """Canonical wire-dtype name, or ``None`` for the identity wire
    (``None``/``"float32"`` — ship rows as computed). Unknown names raise
    eagerly with the supported set, so launcher typos fail before any
    device work."""
    if name is None or name == "float32":
        return None
    if name not in WIRE_DTYPES:
        raise ValueError(f"unknown wire_dtype {name!r}: expected one of "
                         f"{WIRE_DTYPE_NAMES}")
    return name


def is_quantized(name):
    """True for wire dtypes that need per-row scales (int8 / fp8); the
    bf16 wire is a plain cast."""
    return name in QMAX


def wire_itemsize(name):
    """Bytes per element on the wire (1 for int8/fp8, 2 for bf16)."""
    return jnp.dtype(WIRE_DTYPES[name]).itemsize


def quantize_rows(x, wire_dtype):
    """Per-row symmetric quantization of ``(R, D)`` float rows into the
    ``wire_dtype`` grid. Returns ``(q, scales)``: ``q`` of the wire dtype
    and f32 ``scales`` of shape ``(R,)`` with ``x ~= q * scales[:, None]``.
    All-zero rows get scale 0 and quantize to exact zeros. This is the
    jnp reference semantics the fused ``kernels/quant_permute`` Pallas
    kernels reproduce bit-for-bit."""
    qmax = QMAX[wire_dtype]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=1)
    # multiply by the f32 reciprocal (not divide): bit-identical to the
    # Pallas kernels' scale computation under XLA's constant rewrites
    scale = amax * jnp.float32(1.0 / qmax)
    inv = jnp.where(amax > 0, qmax / jnp.where(amax > 0, amax, 1.0), 0.0)
    y = xf * inv[:, None]
    if jnp.issubdtype(WIRE_DTYPES[wire_dtype], jnp.integer):
        y = jnp.round(y)
    return y.astype(WIRE_DTYPES[wire_dtype]), scale


def dequantize_rows(q, scales, out_dtype):
    """Inverse of :func:`quantize_rows`: ``(R, D)`` wire rows times their
    per-row f32 scales, cast to ``out_dtype``."""
    return (q.astype(jnp.float32) * scales[:, None]).astype(out_dtype)


def pack_scales(scales, wire_dtype):
    """Bitcast ``(R,)`` f32 scales into ``(R, SCALE_LANES)`` one-byte
    lanes of the (quantized) wire dtype, ready to concatenate as extra
    payload columns — rows and scales cross the collective as ONE operand
    in the wire dtype."""
    lanes = jax.lax.bitcast_convert_type(scales, jnp.uint8)
    return jax.lax.bitcast_convert_type(lanes, WIRE_DTYPES[wire_dtype])


def unpack_scales(lanes):
    """Inverse of :func:`pack_scales`: ``(R, SCALE_LANES)`` one-byte wire
    lanes back to ``(R,)`` f32 scales."""
    u8 = jax.lax.bitcast_convert_type(lanes, jnp.uint8)
    return jax.lax.bitcast_convert_type(u8, jnp.float32)
