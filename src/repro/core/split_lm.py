"""SFPL as a first-class feature of the LM training loop.

In the multi-pod deployment each data shard plays the role of a client group
holding positive-only data; the cut after ``cut_groups`` scan groups is the
client/server model boundary; the global-collector shuffle is a batch
permutation of the smashed data (all-to-all over the data axis); the
de-shuffling gradient routing of Algorithm 1 is the VJP of that gather.

Norm-layer policy for transformer stacks: RMSNorm/LayerNorm carry no running
statistics, so the RMSD/CMSD distinction is moot (DESIGN.md
§Arch-applicability); the FedBN-style *non-aggregation of norm parameters*
corresponds in synchronous SPMD training to norm params being identical
across shards by construction — recorded here for completeness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sfpl_lm_loss(model, params, batch_in, cfg, *, perm, cut_groups=1,
                 training=True):
    """Loss with SFPL collector shuffle at the cut layer.

    ``model`` is a module exposing forward(params, batch, cfg, ...,
    collector_perm=, cut_groups=). Labels are permuted to follow their
    smashed data (the paper ships (A_k, Y_k) pairs through the collector
    together).
    """
    from repro.models.common import chunked_lm_loss

    hidden, aux = model.forward(params, batch_in, cfg, training=training,
                                collector_perm=perm, cut_groups=cut_groups,
                                return_hidden=True)
    labels = jnp.take(batch_in["labels"], perm, axis=0)
    loss = chunked_lm_loss(hidden, labels,
                           lambda xc: model.unembed(params, xc, cfg))
    coef = getattr(cfg, "router_aux_coef", 0.0)
    return loss + coef * aux, {"xent": loss, "aux": aux}


def make_collector_perm(key, global_batch):
    return jax.random.permutation(key, global_batch)
