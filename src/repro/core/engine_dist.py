"""Mesh-sharded round engines (the paper's schemes at fleet scale).

``engine.sfpl_epoch`` simulates every client on one device; the server-side
update over the pooled smashed-data batch is the scaling bottleneck (the
same framing as SplitFed, arXiv:2004.12088). The entrypoints here run the
SAME step bodies as the single-device engine — ``repro.core.round`` — with
a ``DataMesh`` placement over a ``("data",)`` axis, or over the 2-D
multi-host ``("pod", "data")`` mesh (``make_data_mesh(..., pods=...)``
after ``launch.multihost.initialize``), whose pod-major flattened device
index is the collector shard index:

  * SFPL: client params / BN state / optimizer state are sharded on the
    leading client axis; the pooled smashed stack (N*B rows, client-major)
    inherits that sharding; the collector shuffle is ONE explicit
    ``jax.lax.all_to_all`` per exchange direction (``MeshAllToAll``
    strategy over a per-step precomputed ``RoutePlan`` — rows only, no
    position/validity traffic). Gradient DE-shuffling is not coded
    anywhere: the server loss is a function of the pre-shuffle pooled
    stack, so autodiff emits the exchange under the plan's backward half.
    Collector modes: "balanced" (drop-free block permutations; per-flush-
    group when ``alpha < 1``, aligned to shard boundaries) and "uniform"
    (paper-faithful uniform shuffle, slack auto-sized from probe
    ``max_pair_load`` with the in-graph capacity check forced on).
    Collector pipelines: "sync" (one blocking exchange per step — the
    parity oracle) and "double_buffered" (the paper's threshold-queue
    collector streamed: per-flush-group issue/complete exchanges
    overlapping the next group's client forward, final group drained
    after the loop). See docs/ARCHITECTURE.md for the dataflow.
  * SFLv2: the deliberate sequential client visitation (the catastrophic-
    forgetting mechanism under study) is preserved; the per-client batch
    axis — and with it the server-side stream — is sharded instead.

Numerics: the SFPL server update is permutation-invariant (mean loss +
batch-stat BN over the whole pool), so swapping the uniform pool shuffle
for balanced exchanges leaves the loss trajectory unchanged up to float
reduction order — every sharded entrypoint matches its single-device
counterpart within 1e-4 on the same seed (tests/test_engine_dist.py,
8 forced host devices).

``make_sfpl_epoch_sharded`` / ``make_sflv2_epoch_sharded`` jit the epoch
with the carried state DONATED, so parameter/optimizer buffers are updated
in place shard-by-shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collector as C
from repro.core import round as RD
from repro.core.collector_dist import (group_fits_slabs, mesh_axis_size,
                                       submesh_slice_size)
from repro.core.engine import SplitModel, make_client_update  # noqa: F401
from repro.core.wire import resolve_wire_dtype


def make_data_mesh(num_shards=None, *, pods=None, axis="data",
                   pod_axis="pod"):
    """Collector mesh over (up to) all visible devices.

    ``pods=None`` (default) builds the historical 1-D ``(num_shards,)``
    mesh over ``axis``. With ``pods`` set, the mesh is the 2-D multi-host
    topology ``(pods, num_shards // pods)`` over ``(pod_axis, axis)`` —
    one pod per host process when built after
    ``launch.multihost.initialize`` (``jax.make_mesh`` orders devices
    process-major, so pod ``p`` is process ``p``'s local devices). The
    collector axis of a pod mesh is the name TUPLE ``(pod_axis, axis)``
    (``collector_axis`` resolves it), flattening pod-major to the shard
    index.

    >>> make_data_mesh(4, pods=3)
    Traceback (most recent call last):
        ...
    ValueError: pods=3 must be >= 1 and divide num_shards=4 (each pod \
holds an equal contiguous slice of the flattened shard axis)
    """
    num_shards = num_shards or len(jax.devices())
    if pods is None:
        return jax.make_mesh((num_shards,), (axis,))
    if pods < 1 or num_shards % pods:
        raise ValueError(
            f"pods={pods} must be >= 1 and divide num_shards="
            f"{num_shards} (each pod holds an equal contiguous slice of "
            f"the flattened shard axis)")
    return jax.make_mesh((pods, num_shards // pods), (pod_axis, axis))


def collector_axis(mesh, *, axis="data", pod_axis="pod"):
    """The mesh axis (name or pod-major name tuple) the collector shards
    over: ``(pod_axis, axis)`` on a pod mesh, the bare ``axis`` on the
    1-D mesh. Every ``axis=None`` entrypoint below resolves through
    this, so callers never spell the tuple by hand."""
    return (pod_axis, axis) if pod_axis in mesh.axis_names else axis


def _resolve_axis(mesh, axis):
    return collector_axis(mesh) if axis is None else axis


def shard_dcml_state(st, mesh, *, axis=None):
    """Place a ``init_dcml_state`` tree on the mesh: client-stacked leaves
    sharded on their leading (client) axis, server leaves replicated.
    ``axis=None`` resolves via ``collector_axis`` (the pod-major tuple on
    a pod mesh); on a multi-host mesh each process contributes its
    addressable slice of the replicated host tree."""
    return RD.DataMesh(mesh, _resolve_axis(mesh, axis)).place_state(st)


def shard_client_data(data, mesh, *, axis=None):
    """Shard the per-client dataset {"x": (N, n, ...), "y": (N, n)} over the
    client axis (``axis=None``: ``collector_axis`` resolution)."""
    return RD.DataMesh(mesh, _resolve_axis(mesh, axis)).place_data(data)


def check_sfpl_layout(num_clients, batch_size, n_shards, *, alpha=1.0,
                      collector_mode="balanced",
                      collector_pipeline="sync",
                      collector_submesh=None, pods=None,
                      participation=None, wire_dtype=None,
                      wire_dtype_bwd=None):
    """Eager validation of the sharded SFPL layout; raises ValueError with
    an actionable message before any device work.

    Requirements: clients divide evenly over shards. In balanced mode,
    every flush group of the ``alpha`` accumulation threshold must cover
    whole shard slabs (so the grouped permutation never crosses a shard
    mid-group) or live entirely inside one slab (no exchange needed), and
    each multi-shard group's shard count must divide the slab so equal
    blocks can be exchanged. Uniform mode has no alignment requirement —
    its slack is probed from the actual flush-group structure. The
    ``double_buffered`` pipeline additionally needs every flush group's
    row count divisible by the shard count (each group is row-sharded
    over the whole mesh for its own issue/complete exchange) — UNLESS
    the layout qualifies for sub-mesh routing (``collector_submesh`` not
    ``False``, balanced mode, ``collector_dist.submesh_slice_size``),
    where each group's exchange is confined to its owning shard slice and
    the whole-mesh divisibility is moot. ``collector_submesh=True``
    demands qualification and raises otherwise.

    ``pods`` declares the 2-D ``("pod", "data")`` topology the shards run
    on (``make_data_mesh(n_shards, pods=...)``): it must divide
    ``n_shards``, and sub-mesh qualification tightens to POD-LOCAL slices
    — the owning slice must be the whole mesh or divide the per-pod shard
    count, since a slice straddling pods has no grouped-collective
    expression. Non-qualifying pod layouts are still valid (the streamed
    exchange falls back to the probed-slack whole-mesh path, logged), but
    ``collector_submesh=True`` raises on them.

    ``participation`` (optional elastic-participation mask,
    ``(num_clients,)`` or ``(steps, num_clients)``) is validated against
    the flush-group structure — wrong length, or any flush group left
    with zero surviving clients, raises a ValueError naming the group
    (``collector.check_participation``).

    ``wire_dtype`` / ``wire_dtype_bwd`` (the exchange wire-format knobs
    — see ``core.wire``) are name-checked here too, so a launcher typo
    fails with the supported set before any device work:

    >>> check_sfpl_layout(8, 8, 8, wire_dtype="int4")  # doctest: +ELLIPSIS
    Traceback (most recent call last):
        ...
    ValueError: unknown wire_dtype 'int4': expected one of ...

    Returns the flush-group row counts of the accepted layout:

    >>> check_sfpl_layout(8, 8, 8, wire_dtype="int8")
    [64]
    >>> check_sfpl_layout(8, 8, 8)
    [64]
    >>> check_sfpl_layout(8, 8, 8, alpha=0.5,
    ...     participation=[1, 1, 1, 1, 0, 0, 0, 0])  # doctest: +ELLIPSIS
    Traceback (most recent call last):
        ...
    ValueError: participation mask drops ALL clients of flush group 1 ...
    >>> check_sfpl_layout(8, 8, 8, alpha=0.5)
    [32, 32]
    >>> check_sfpl_layout(8, 8, 8, alpha=0.25, collector_submesh=True,
    ...                   collector_pipeline="double_buffered")
    [16, 16, 16, 16]
    >>> check_sfpl_layout(8, 8, 8, alpha=0.5, pods=2,
    ...                   collector_pipeline="double_buffered")
    [32, 32]
    >>> check_sfpl_layout(8, 8, 4, alpha=0.5, pods=4,
    ...                   collector_submesh=True,
    ...                   collector_pipeline="double_buffered")
    Traceback (most recent call last):
        ...
    ValueError: collector_submesh=True needs collector_mode='balanced' \
and every flush group covering the same number of whole shard slabs, \
with the slab divisible by that span — pod-local (the whole mesh, or \
dividing the 1 shards per pod) when pods=4; got mode='balanced', group \
sizes [32, 32] over 4 shards (num_clients=8, batch_size=8, alpha=0.5)
    """
    resolve_wire_dtype(wire_dtype)
    resolve_wire_dtype(wire_dtype_bwd)
    if num_clients % n_shards:
        raise ValueError(
            f"num_clients={num_clients} must divide evenly over "
            f"{n_shards} shards")
    if participation is not None:
        C.check_participation(num_clients, participation, alpha=alpha)
    if pods is not None and (pods < 1 or n_shards % pods):
        raise ValueError(
            f"pods={pods} must be >= 1 and divide n_shards={n_shards} "
            f"(each pod holds an equal contiguous slice of the flattened "
            f"shard axis)")
    n_pool = num_clients * batch_size
    b = n_pool // n_shards
    rows = [c * batch_size
            for c in C.flush_group_sizes(num_clients, alpha)]
    if collector_pipeline == "double_buffered":
        slices = submesh_slice_size(n_pool, n_shards, rows)
        if (slices is not None and pods is not None
                and slices != n_shards
                and (n_shards // pods) % slices):
            slices = None        # slice straddles a pod: whole-mesh path
        sub_ok = (collector_submesh is not False
                  and collector_mode == "balanced"
                  and slices is not None)
        if collector_submesh and not sub_ok:
            pod_req = ("" if pods is None else
                       f" — pod-local (the whole mesh, or dividing the "
                       f"{n_shards // pods} shards per pod) when "
                       f"pods={pods}")
            raise ValueError(
                f"collector_submesh=True needs collector_mode='balanced' "
                f"and every flush group covering the same number of whole "
                f"shard slabs, with the slab divisible by that span"
                f"{pod_req}; got "
                f"mode={collector_mode!r}, group sizes {rows} over "
                f"{n_shards} shards (num_clients={num_clients}, "
                f"batch_size={batch_size}, alpha={alpha})")
        bad = [size for size in rows if size % n_shards]
        if bad and not sub_ok:
            raise ValueError(
                f"double_buffered collector needs every flush group's row "
                f"count divisible by the {n_shards} shards (each group is "
                f"row-sharded over the whole mesh for its own exchange), "
                f"or a balanced layout qualifying for sub-mesh routing; "
                f"got group sizes {rows} (num_clients={num_clients}, "
                f"batch_size={batch_size}, alpha={alpha})")
    if collector_mode != "balanced":
        return rows
    start = 0
    for size in rows:
        aligned, in_slab = group_fits_slabs(start, size, b)
        if not (aligned or in_slab):
            raise ValueError(
                f"flush group of {size} rows at offset {start} is not "
                f"aligned to the {b}-row shard slabs: choose alpha/"
                f"num_clients/batch_size so every flush group covers whole "
                f"shards, or use collector_mode='uniform' (num_clients="
                f"{num_clients}, batch_size={batch_size}, shards="
                f"{n_shards}, alpha={alpha})")
        s_g = size // b
        if aligned and s_g > 1 and b % s_g:
            raise ValueError(
                f"balanced exchange needs the {b}-row shard slab divisible "
                f"by the {s_g} shards each flush group spans "
                f"(num_clients={num_clients}, batch_size={batch_size}, "
                f"shards={n_shards}, alpha={alpha})")
        start += size
    return rows


def fit_shards(num_clients, batch_size, *, scheme="sfpl", alpha=1.0,
               collector_mode="balanced", collector_pipeline="sync",
               collector_submesh=None, pods=None, max_shards=None,
               participation=None, wire_dtype=None, wire_dtype_bwd=None):
    """Largest shard count (up to the visible devices) the layout supports
    — shared by the launch drivers so every entrypoint degrades to a
    smaller mesh instead of crashing on indivisible configurations. With
    ``pods`` set, only shard counts divisible into ``pods`` equal pod
    slices are considered (``make_data_mesh(s, pods=pods)`` must be
    buildable), and sub-mesh qualification is checked pod-locally.

    ``participation`` and the wire-dtype names are validated ONCE up
    front (both checks are shard-independent): a bad mask or a wire
    typo raises immediately instead of being swallowed by the
    per-shard-count search and silently degrading to the 1-shard
    fallback."""
    resolve_wire_dtype(wire_dtype)
    resolve_wire_dtype(wire_dtype_bwd)
    if participation is not None:
        C.check_participation(num_clients, participation, alpha=alpha)
    max_shards = max_shards or len(jax.devices())
    for s in range(max_shards, 0, -1):
        if pods is not None and s % pods:
            continue
        if scheme == "sflv2":
            if batch_size % s == 0:
                return s
            continue
        try:
            check_sfpl_layout(num_clients, batch_size, s, alpha=alpha,
                              collector_mode=collector_mode,
                              collector_pipeline=collector_pipeline,
                              collector_submesh=collector_submesh,
                              pods=pods)
            return s
        except ValueError:
            continue
    # minimal fallback: one shard per pod (a (pods, 1) mesh), one shard
    # total on the 1-D mesh
    return pods if pods else 1


def sfpl_epoch_sharded(key, st, data, split: SplitModel, opt_c, opt_s, *,
                       mesh, num_clients, batch_size, bn_mode="cmsd",
                       alpha=1.0, use_kernel=None, slack=None,
                       check_capacity=False, axis=None,
                       collector_mode="balanced",
                       collector_pipeline="sync", stream_slack=None,
                       collector_submesh=None, participation=None,
                       wire_dtype=None, wire_dtype_bwd=None):
    """Drop-in sharded replacement for ``engine.sfpl_epoch``.

    Shape/layout contract: ``st`` is an ``init_dcml_state`` tree placed by
    ``shard_dcml_state`` (client-stacked leaves sharded on their leading
    client axis, server leaves replicated); ``data`` is the
    ``{"x": (N, n, ...), "y": (N, n)}`` per-client set placed by
    ``shard_client_data``; ``num_clients`` must divide over the mesh's
    ``axis``. Returns ``(st, losses)`` with ``losses`` of shape
    ``(n // batch_size,)``.

    ``alpha < 1`` runs per-flush-group balanced permutations aligned to
    shard boundaries; ``collector_mode="uniform"`` swaps in the paper-
    faithful uniform shuffle with auto-sized slack. ``slack=None``
    auto-sizes the exchange buffers (1.0 for one balanced global flush).
    ``collector_pipeline="double_buffered"`` streams the collector: each
    flush group's all_to_all is issued while the next group's client
    forward computes (``RD.StreamingAllToAll``), with the final in-flight
    group drained after the loop; ``"sync"`` (default) is the blocking
    single-exchange parity oracle. ``collector_submesh`` controls sub-mesh
    routing for the streamed pipeline: ``None`` (default) activates it
    automatically when the balanced grouped layout qualifies — each flush
    group's exchange is then a dense, zero-slack collective confined to
    its owning shard slice via ``axis_index_groups`` — ``True`` demands it
    (ValueError otherwise), ``False`` forces the whole-mesh fallback.
    ``stream_slack`` overrides the whole-mesh streaming fallback's
    per-group buffer sizing (default: probed per distinct group size in
    BOTH modes — ``balanced_stream_slack`` clamped at the capacity-safe
    ``n_shards`` ceiling for balanced permutations, ``uniform_auto_slack``
    for uniform — memoized, with the in-graph capacity check forced on).
    ``use_kernel=None`` (auto, the default) fuses the
    exchange's local bucket gathers into the Pallas
    ``bucket_permute``/``unbucket_permute`` kernels on TPU — where the
    one-pass HBM copies win — and keeps the jnp gathers elsewhere;
    pass True/False to force.

    ``axis=None`` resolves via ``collector_axis``: the bare ``"data"``
    name on a 1-D mesh, the pod-major ``("pod", "data")`` tuple on a pod
    mesh (``make_data_mesh(..., pods=...)``), where the layout check runs
    with the mesh's pod count so sub-mesh routing only claims pod-local
    slices.

    ``participation`` masks absent clients for the epoch (elastic
    participation — see ``round.sfpl_round``). A concrete (host) mask is
    validated eagerly against the flush-group structure; a traced mask
    (already inside a jit) skips the eager check, which the jitting
    caller must then run itself (``make_sfpl_epoch_sharded`` does).

    ``wire_dtype`` / ``wire_dtype_bwd`` narrow the exchange payloads
    (``core.wire``): smashed rows (and optionally the routed-back
    gradient rows) quantize/cast right before each collective and are
    restored right after — per-row f32 scales ride the same collective
    as packed payload columns, so the one-``all_to_all``-per-direction
    contract is unchanged.
    """
    axis = _resolve_axis(mesh, axis)
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    n_shards = mesh_axis_size(mesh, axis)
    pods = (mesh_axis_size(mesh, names[0]) if len(names) > 1 else None)
    part_host = (participation
                 if participation is not None
                 and not isinstance(participation, jax.core.Tracer)
                 else None)
    check_sfpl_layout(num_clients, batch_size, n_shards, alpha=alpha,
                      collector_mode=collector_mode,
                      collector_pipeline=collector_pipeline,
                      collector_submesh=collector_submesh, pods=pods,
                      participation=part_host, wire_dtype=wire_dtype,
                      wire_dtype_bwd=wire_dtype_bwd)
    placement = RD.DataMesh(mesh, axis)
    return RD.sfpl_round(
        key, st, data, split, opt_c, opt_s, num_clients=num_clients,
        batch_size=batch_size, bn_mode=bn_mode,
        collector=placement.collector(
            num_clients, alpha=alpha, mode=collector_mode, slack=slack,
            use_kernel=use_kernel, check_capacity=check_capacity,
            pipeline=collector_pipeline, stream_slack=stream_slack,
            submesh=collector_submesh, wire_dtype=wire_dtype,
            wire_dtype_bwd=wire_dtype_bwd),
        participation=participation)


def make_sfpl_epoch_sharded(split: SplitModel, opt_c, opt_s, data, *,
                            mesh, num_clients, batch_size, **kw):
    """Jitted hot loop: ``(key, st[, participation]) -> (st, losses)``
    with the carried state donated, so the sharded param/opt buffers are
    reused in place.

    ``data`` is bound as a jit ARGUMENT, not a closure: multi-host global
    arrays span non-addressable devices and jax refuses to close over
    them, while passing them through the jit boundary is fine.

    The returned callable takes an optional ``participation`` mask
    (``(num_clients,)`` or ``(steps, num_clients)`` bool) for elastic
    rounds. It is validated eagerly on the host (>= 1 survivor per flush
    group — so fully-dropped flush groups, and with them the streamed
    skip fast path, cannot arise here) and then rides through the jit
    boundary as a TRACED argument: every epoch's mask reuses one
    specialization instead of retracing per draw of a fault schedule.
    ``None`` and masked epochs are separate specializations (two
    traces)."""
    alpha = kw.get("alpha", 1.0)

    def epoch(key, st, data, participation=None):
        return sfpl_epoch_sharded(key, st, data, split, opt_c, opt_s,
                                  mesh=mesh, num_clients=num_clients,
                                  batch_size=batch_size,
                                  participation=participation, **kw)
    jitted = jax.jit(epoch, donate_argnums=(1,))

    def run(key, st, participation=None):
        if participation is None:
            return jitted(key, st, data)
        mask = C.check_participation(num_clients, participation,
                                     alpha=alpha)
        return jitted(key, st, data, jnp.asarray(mask))
    return run


def sflv2_epoch_sharded(key, st, data, split: SplitModel, opt_c, opt_s, *,
                        mesh, num_clients, batch_size, aggregate_bn=True,
                        axis=None):
    """Drop-in sharded replacement for ``engine.sflv2_epoch``: the server
    stream is sharded over the per-client batch axis while the sequential
    client-visitation order is preserved bit-for-bit. State and data stay
    replicated (the visitation loop touches one client at a time); call it
    under jit (``make_sflv2_epoch_sharded``) so the batch sharding
    constraints drive the partitioner.

    Shape/layout contract: ``st`` is an UNSHARDED ``init_dcml_state``
    tree and ``data`` the unsharded ``{"x": (N, n, ...), "y": (N, n)}``
    per-client set (contrast ``sfpl_epoch_sharded``); ``batch_size`` must
    divide over the mesh's ``axis``. Returns ``(st, losses)`` with
    ``losses`` of shape ``(N, n // batch_size)`` in visitation order."""
    axis = _resolve_axis(mesh, axis)
    n_shards = mesh_axis_size(mesh, axis)
    if batch_size % n_shards:
        raise ValueError(
            f"batch_size={batch_size} must divide evenly over {n_shards} "
            f"shards to shard the SFLv2 server stream")
    return RD.sflv2_round(
        key, st, data, split, opt_c, opt_s, num_clients=num_clients,
        batch_size=batch_size, aggregate_bn=aggregate_bn,
        placement=RD.DataMesh(mesh, axis))


def make_sflv2_epoch_sharded(split: SplitModel, opt_c, opt_s, data, *,
                             mesh, num_clients, batch_size, **kw):
    """Jitted hot loop: ``(key, st) -> (st, losses)``, state donated;
    ``data`` rides through the jit boundary as an argument (see
    ``make_sfpl_epoch_sharded``)."""
    def epoch(key, st, data):
        return sflv2_epoch_sharded(key, st, data, split, opt_c, opt_s,
                                   mesh=mesh, num_clients=num_clients,
                                   batch_size=batch_size, **kw)
    jitted = jax.jit(epoch, donate_argnums=(1,))
    return lambda key, st: jitted(key, st, data)
