"""Mesh-sharded SFPL round engine (the paper's Algorithm 1 at fleet scale).

``engine.sfpl_epoch`` simulates every client on one device; the server-side
update over the pooled smashed-data batch is the scaling bottleneck (the
same framing as SplitFed, arXiv:2004.12088). This engine shards BOTH the
client axis and the pooled batch over a ``("data",)`` mesh:

  * client params / BN state / optimizer state: leading client axis N is
    sharded, so client forward+backward run data-parallel across the mesh;
  * the pooled smashed stack (N*B rows, client-major) inherits that
    sharding — each shard owns the rows of its resident clients;
  * the global collector shuffle is ``make_balanced_perm`` +
    ``shuffle_shard_map`` — one explicit ``jax.lax.all_to_all`` per step,
    drop-free at ``slack=1.0`` by construction;
  * gradient DE-shuffling is not coded anywhere: the server loss is taken
    as a function of the *pre-shuffle* pooled stack, so autodiff through
    the sharded gather emits the inverse all_to_all and hands every client
    exactly its own activation gradients;
  * server params stay replicated; their gradient (a mean over the sharded
    pooled batch) is psum'd by the partitioner.

Numerics: the SFPL server update is permutation-invariant (mean loss +
batch-stat BN over the whole pool), so swapping the uniform pool shuffle
for the balanced one leaves the loss trajectory unchanged up to float
reduction order — ``sfpl_epoch_sharded`` matches ``sfpl_epoch`` within
1e-4 on the same seed (tests/test_engine_dist.py, 8 forced host devices).

``make_sfpl_epoch_sharded`` jits the epoch with the carried state DONATED,
so parameter/optimizer buffers are updated in place shard-by-shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collector as C
from repro.core.bn_policy import fedavg, aggregate_bn_state
from repro.core.collector_dist import (
    make_balanced_perm, mesh_axis_size, shuffle_shard_map)
from repro.core.engine import SplitModel, make_client_update


def make_data_mesh(num_shards=None, *, axis="data"):
    """1-D collector mesh over (up to) all local devices."""
    num_shards = num_shards or len(jax.devices())
    return jax.make_mesh((num_shards,), (axis,))


def shard_dcml_state(st, mesh, *, axis="data"):
    """Place a ``init_dcml_state`` tree on the mesh: client-stacked leaves
    sharded on their leading (client) axis, server leaves replicated."""
    shard = NamedSharding(mesh, P(axis))
    repl = NamedSharding(mesh, P())
    put = lambda t, s: jax.tree_util.tree_map(
        lambda a: jax.device_put(a, s), t)
    return dict(
        st,
        cp=put(st["cp"], shard), cbn=put(st["cbn"], shard),
        copt=put(st["copt"], shard),
        sp=put(st["sp"], repl), sbn=put(st["sbn"], repl),
        sopt=put(st["sopt"], repl), step=jax.device_put(st["step"], repl))


def shard_client_data(data, mesh, *, axis="data"):
    """Shard the per-client dataset {"x": (N, n, ...), "y": (N, n)} over the
    client axis."""
    shard = NamedSharding(mesh, P(axis))
    return jax.tree_util.tree_map(lambda a: jax.device_put(a, shard), data)


def sfpl_epoch_sharded(key, st, data, split: SplitModel, opt_c, opt_s, *,
                       mesh, num_clients, batch_size, bn_mode="cmsd",
                       alpha=1.0, use_kernel=False, slack=1.0,
                       check_capacity=False, axis="data"):
    """Drop-in sharded replacement for ``engine.sfpl_epoch``.

    Constraints: ``num_clients`` divisible by the mesh size S, and the
    per-shard slab ``num_clients/S * batch_size`` divisible by S (the
    balanced permutation exchanges equal blocks). ``alpha`` < 1 (partial
    collector flushes) is not sharded yet — see ROADMAP open items.
    """
    if alpha != 1.0:
        raise NotImplementedError(
            "sharded collector currently requires alpha=1.0 (one global "
            "flush); partial flush groups are a single-device feature")
    n_shards = mesh_axis_size(mesh, axis)
    assert num_clients % n_shards == 0, (num_clients, n_shards)
    n_pool = num_clients * batch_size
    assert (n_pool // n_shards) % n_shards == 0, (n_pool, n_shards)

    n_local = data["x"].shape[1]
    steps = n_local // batch_size
    client_upd = make_client_update(split, opt_c)

    def one_step(carry, idx):
        st, key = carry
        key, kperm = jax.random.split(key)
        xb = jax.lax.dynamic_slice_in_dim(data["x"], idx * batch_size,
                                          batch_size, axis=1)
        yb = jax.lax.dynamic_slice_in_dim(data["y"], idx * batch_size,
                                          batch_size, axis=1)

        # 1. client forward, data-parallel over the sharded client axis
        A, ncbn = jax.vmap(
            lambda cp, cs, x: split.client_fwd(cp, cs, x, True, None)
        )(st["cp"], st["cbn"], xb)

        # 2. global collector: pool (client-major rows keep the client
        # sharding) + balanced shuffle via explicit all_to_all
        a_pool = A.reshape((n_pool,) + A.shape[2:])
        y_pool = yb.reshape((n_pool,))
        perm = make_balanced_perm(kperm, n_pool, n_shards)
        y_shuf = shuffle_shard_map(y_pool, perm, mesh=mesh, slack=slack,
                                   check_capacity=check_capacity)

        # 3. ONE server update on the shuffled stack. Differentiating w.r.t.
        # the PRE-shuffle pool makes autodiff emit the de-shuffling
        # all_to_all: g_pool arrives already routed back to source clients.
        def srv_loss(sp, a_pool):
            a_shuf = shuffle_shard_map(a_pool, perm, mesh=mesh, slack=slack,
                                       use_kernel=use_kernel,
                                       check_capacity=check_capacity)
            loss, (nss, _) = split.server_loss(sp, st["sbn"], a_shuf, y_shuf,
                                               True, None)
            return loss, nss
        (loss, nsbn), (g_sp, g_pool) = jax.value_and_grad(
            srv_loss, argnums=(0, 1), has_aux=True)(st["sp"], a_pool)
        sp_new, sopt_new = opt_s.update(g_sp, st["sopt"], st["sp"],
                                        st["step"])

        # 4. client backprop, data-parallel (dA is sharded like A)
        dA = g_pool.reshape(A.shape)
        cp_new, copt_new, ncbn2 = jax.vmap(
            lambda cp, cbn, copt, x, da: client_upd(cp, cbn, copt, x, da,
                                                    st["step"]))(
            st["cp"], ncbn, st["copt"], xb, dA)

        st = dict(st, cp=cp_new, cbn=ncbn2, sp=sp_new, sbn=nsbn,
                  copt=copt_new, sopt=sopt_new, step=st["step"] + 1)
        return (st, key), loss

    (st, _), losses = jax.lax.scan(one_step, (st, key), jnp.arange(steps))

    # 5. ClientFedServer: FedAvg across the sharded client axis (all-reduce
    # under the hood); BN treatment per bn_mode as in sfpl_epoch
    exclude = bn_mode == "cmsd"
    st = dict(st, cp=fedavg(st["cp"], exclude_bn=exclude),
              cbn=aggregate_bn_state(st["cbn"], aggregate=not exclude))
    return st, losses


def make_sfpl_epoch_sharded(split: SplitModel, opt_c, opt_s, data, *,
                            mesh, num_clients, batch_size, **kw):
    """Jitted hot loop: ``(key, st) -> (st, losses)`` with the carried state
    donated, so the sharded param/opt buffers are reused in place."""
    def epoch(key, st):
        return sfpl_epoch_sharded(key, st, data, split, opt_c, opt_s,
                                  mesh=mesh, num_clients=num_clients,
                                  batch_size=batch_size, **kw)
    return jax.jit(epoch, donate_argnums=(1,))
