"""SFPL's global collector function (paper Algorithm 1).

The collector accumulates smashed data + labels from all clients, applies a
random shuffle before server-side training, and de-shuffles the returned
activation gradients so each slice is routed back to its source client.

Three implementations with identical semantics:
  * ``shuffle`` / ``deshuffle``           — jnp take (simulation default)
  * ``shuffle(..., use_kernel=True)``     — Pallas gather kernel
  * ``distributed_shuffle``               — mesh-aware: the pooled batch axis
    is sharded over ("pod","data"); a global permutation gather compiles to
    all-to-all / collective-permute on the data axis (the paper's
    "collect from all clients then scatter back" — without ever
    materializing the pool on one device).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_permutation(key, n):
    return jax.random.permutation(key, n)


def inverse_permutation(perm):
    return jnp.argsort(perm)


def _permute_leaf(x, perm, use_kernel, interpret):
    if use_kernel:
        from repro.kernels.collector_permute.ops import collector_permute_ad
        return collector_permute_ad(x, perm, interpret)
    return jnp.take(x, perm, axis=0)


def shuffle(tree, perm, *, use_kernel=False, interpret=True):
    """Apply ``perm`` along axis 0 of every leaf (smashed data + labels)."""
    return jax.tree_util.tree_map(
        lambda x: _permute_leaf(x, perm, use_kernel, interpret), tree)


def deshuffle(tree, perm, *, use_kernel=False, interpret=True):
    """Inverse of ``shuffle`` — routes gradients back to source clients."""
    inv = inverse_permutation(perm)
    return jax.tree_util.tree_map(
        lambda x: _permute_leaf(x, inv, use_kernel, interpret), tree)


def collect(per_client_tree):
    """Stack per-client tensors (N, B, ...) into the pooled stack (N*B, ...).

    Mirrors the paper's ActivationStack/LabelStack keyed by client id: row
    ``k * B + j`` is sample j of client k, so ``uncollect`` can route
    results back deterministically.
    """
    return jax.tree_util.tree_map(
        lambda x: x.reshape((-1,) + x.shape[2:]), per_client_tree)


def uncollect(pooled_tree, num_clients):
    """Inverse of ``collect``: (N*B, ...) -> (N, B, ...)."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape((num_clients, -1) + x.shape[1:]), pooled_tree)


def flush_group_sizes(num_clients, alpha):
    """Clients per collector flush under the paper's accumulation threshold:
    the collector flushes every ceil(alpha*N) client batches, so alpha=1 is
    one global flush and alpha=0.5 with N=10 gives two 5-client pools."""
    fc = max(1, min(num_clients, round(alpha * num_clients)))
    num_flushes = -(-num_clients // fc)
    return [min(fc, num_clients - f * fc) for f in range(num_flushes)]


def make_flush_perm(key, n, num_clients, alpha):
    """Pool permutation honouring the accumulation threshold: rows are
    shuffled within contiguous client-major flush groups, never across
    group boundaries. The canonical single-device collector permutation —
    the mesh strategies reproduce its group structure with balanced
    per-group exchanges (collector_dist.make_grouped_balanced_perm)."""
    groups = flush_group_sizes(num_clients, alpha)
    if len(groups) <= 1:
        return make_permutation(key, n)
    per_client = n // num_clients
    parts, start = [], 0
    for f, c in enumerate(groups):
        size = c * per_client
        sub = make_permutation(jax.random.fold_in(key, f), size)
        parts.append(sub + start)
        start += size
    return jnp.concatenate(parts)


def check_participation(num_clients, participation, *, alpha=1.0):
    """Validate an elastic-participation mask eagerly (host side).

    ``participation`` is a bool mask of shape ``(num_clients,)`` (static
    per-epoch) or ``(steps, num_clients)`` (per-step).  Every flush group
    must keep at least one surviving client — an all-absent group would
    leave its pooled slice with zero valid rows and the server update for
    that slice undefined.  Raises ``ValueError`` naming the offending
    flush group (and step, for per-step masks); returns the mask as a
    numpy bool array.

    >>> import numpy as np
    >>> check_participation(4, [True, False, True, True], alpha=0.5)
    array([ True, False,  True,  True])
    >>> check_participation(4, [True, True, False, False],
    ...                     alpha=0.5)  # doctest: +ELLIPSIS
    Traceback (most recent call last):
        ...
    ValueError: participation mask drops ALL clients of flush group 1 ...
    """
    import numpy as np
    if participation is None:
        return None
    mask = np.asarray(participation)
    if mask.ndim not in (1, 2) or mask.shape[-1] != num_clients:
        raise ValueError(
            f"participation mask must have shape ({num_clients},) or "
            f"(steps, {num_clients}); got {mask.shape}")
    mask = mask.astype(bool)
    groups = flush_group_sizes(num_clients, alpha)
    rows = mask[None] if mask.ndim == 1 else mask
    start = 0
    for g, c in enumerate(groups):
        alive = rows[:, start:start + c].any(axis=1)
        if not alive.all():
            step = int(np.argmin(alive))
            at = "" if mask.ndim == 1 else f" at step {step}"
            raise ValueError(
                f"participation mask drops ALL clients of flush group {g} "
                f"(clients {start}..{start + c}, alpha={alpha}){at} — "
                f"each flush group needs >= 1 surviving client")
        start += c
    return mask


def participation_row_mask(mask, batch_size):
    """Expand a per-client mask to the client-major pooled row mask:
    row ``k * batch_size + j`` is valid iff client ``k`` participates."""
    return jnp.repeat(jnp.asarray(mask, dtype=bool), batch_size)


def distributed_shuffle(x, perm):
    """Mesh-aware collector: ``x`` is the pooled global batch whose leading
    axis is sharded over ("pod","data")). A gather by a global permutation is
    SPMD-partitioned by XLA into all-to-all / collective-permute exchanges —
    the TPU-native form of the paper's collect-shuffle-scatter.

    Differentiable: the VJP of the gather is the de-shuffling scatter, so the
    returned-gradient routing of Algorithm 1 falls out of autodiff.
    """
    return jnp.take(x, perm, axis=0)


class GlobalCollector:
    """Stateful convenience wrapper for the simulation engine.

    ``alpha`` mirrors the paper's accumulation threshold (the collector waits
    for ``alpha * N`` client batches before shuffling). In the synchronous
    simulation every client contributes each round, so alpha scales how many
    pooled batches form one shuffle unit.
    """

    def __init__(self, num_clients, *, alpha=1.0, use_kernel=False):
        self.num_clients = num_clients
        self.alpha = alpha
        self.use_kernel = use_kernel

    def make_pool_perm(self, key, n):
        """Permutation honouring the paper's accumulation threshold (see
        ``make_flush_perm``): alpha=1 -> one global shuffle; alpha=0.5 with
        N=10 -> two independent 5-client pools."""
        return make_flush_perm(key, n, self.num_clients, self.alpha)

    def shuffle_pool(self, key, per_client_acts, per_client_labels):
        pooled = collect({"a": per_client_acts, "y": per_client_labels})
        n = pooled["a"].shape[0]
        perm = self.make_pool_perm(key, n)
        shuffled = shuffle(pooled, perm, use_kernel=self.use_kernel)
        return shuffled["a"], shuffled["y"], perm

    def deshuffle_grads(self, grads_pool, perm):
        d = deshuffle({"g": grads_pool}, perm, use_kernel=self.use_kernel)
        return uncollect(d, self.num_clients)["g"]
