"""Explicit-collective distributed collector (shard_map + all_to_all).

`collector.distributed_shuffle` lets XLA choose the collectives for the
global permutation gather. This module is the paper-faithful explicit
schedule — Algorithm 1's collect -> shuffle -> scatter written as
`shard_map` with `jax.lax.all_to_all` — organised around a precomputed
**route plan**:

  1. every data shard (client group) holds a (B_local, ...) slab of smashed
     data;
  2. because the permutation is REPLICATED, the routing metadata — the
     scatter-based O(n) inverse permutation, each row's destination shard,
     its slot in the send bucket, and the receive-side placement — is built
     ONCE per permutation (``build_route_plans``) and shared by the forward
     exchange, the custom-VJP backward exchange, and the streaming
     collector's ``route_back``;
  3. the exchange itself is gather -> ONE ``all_to_all`` -> gather: the
     plan's ``send_idx`` gathers rows directly into send-bucket layout, the
     collective ships the buckets, and ``recv_idx`` gathers received rows
     into output order. No positions or validity masks ever travel over
     the wire — receive placement is derived locally from the plan.

Balanced and grouped-balanced permutations get a **dense fast path**: their
per-pair bucket loads are deterministic (exactly b/S_g rows between the
shards of a flush group), so the plan is built at the exact capacity
(``exact_pair_cap``) with ``may_drop=False`` — zero slack padding for one
global flush, no overflow accounting, no pad row, and both sides of the
exchange are pure row gathers (the shapes the Pallas ``bucket_permute`` /
``unbucket_permute`` kernels fuse into one-pass HBM copies).

The same plan machinery with the inverse permutation is the de-shuffle, so
the gradient routing of Algorithm 1 is one more plan exchange — and because
``plan_shuffle`` registers the backward plan as its custom-VJP residual,
autodiff through the forward shuffle reuses the metadata instead of
re-deriving it (no argsort anywhere on the exchange path; tested in
tests/test_route_plan.py).

Capacity note: a random permutation may route more rows from one source
shard to one destination shard than the bucket holds; slack-buffered plans
(``may_drop=True``) use a per-pair capacity of ``cap = int(B_local *
slack) // n_shards + 1``. Overflowing rows are routed to an out-of-bounds
slot (never clobbering an in-capacity row) and arrive as zeros unless
checked:

  * ``max_pair_load(perm, n_shards)`` — host-side: the worst (src, dst)
    bucket load of a permutation; compare against ``pair_capacity``.
  * ``assert_pair_capacity(perm, ...)`` — host-side hard failure.
  * ``shuffle_shard_map(..., check_capacity=True)`` — in-graph
    ``jax.debug.callback`` on the plan's replicated overflow count that
    raises from inside the jitted program.

Streaming (double-buffered) collector: the exchange is also exposed as two
halves so a software pipeline can put client compute between them —
``plan_exchange_issue`` buckets a slab's rows and hands them to
``all_to_all`` (the in-flight buffer slot), ``plan_exchange_complete``
places the received rows. The slot carries its plan, and the whole shuffle
keeps the inverse-permutation routing: the backward pass is one more
issue/complete exchange with the plan built from the inverse permutation.

Sub-mesh streaming: when the grouped layout QUALIFIES — every flush group
covers the same number ``S`` of whole shard slabs, with ``b % S == 0``
(``submesh_slice_size``) — the streaming path recovers the dense fast path
too. Group ``g``'s rows live exactly on shards ``[g*S, (g+1)*S)``, so its
exchange never needs the rest of the mesh: ``build_submesh_route_plans``
builds a DENSE per-group plan (``may_drop=False``, cap exactly ``b/S``,
no overflow counter, no pad row) whose collective is one ``all_to_all``
restricted to the owning shard slice via ``axis_index_groups``
(``submesh_axis_groups``). The plan's index arrays keep the full-mesh
``(n_shards, b)`` shape so the exchange still runs as ONE pool-width
shard_map — shards outside the slice exchange zero-index garbage within
their own slice and their output rows are masked off by the caller.

Shape/layout contract (all entry points):

  * ``x``: ``(N, ...)`` with dim 0 sharded into ``n_shards`` equal
    ``b = N // n_shards``-row slabs over the mesh ``axis`` — a bare axis
    name on the 1-D mesh, or the pod-major name tuple ``("pod", "data")``
    of the 2-D multi-host mesh, whose flattened (pod-major) device index
    is the shard index (``mesh_axis_size`` multiplies the named sizes and
    ``_plan_collective`` scopes each plan's collective: whole-mesh plans
    run one ``all_to_all`` over the name tuple; pod-local sub-mesh plans
    run over the inner axis only under ``axis_index_groups``);
  * ``perm``: ``(N,)`` int, replicated; output row ``i`` is ``x[perm[i]]``;
  * slack/capacity: each (src, dst) shard pair exchanges at most
    ``pair_capacity(N, n_shards, slack)`` rows — or exactly
    ``exact_pair_cap(N, n_shards, group_sizes)`` on the dense path —

    >>> pair_capacity(64, 8, 1.0)   # slack-buffered: b/S + 1 per pair
    2
    >>> exact_pair_cap(64, 8)       # dense balanced: exactly b/S per pair
    1
    >>> grouped_perm_slack(64, 8, [64])   # one global balanced flush
    1.0
    >>> int(pair_load(np.arange(8), 4).max())   # identity perm: diagonal
    2
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels._compat import get_shard_map
from repro.core.wire import (SCALE_BYTES, SCALE_LANES, WIRE_DTYPES,
                             is_quantized, pack_scales, resolve_wire_dtype,
                             unpack_scales, wire_itemsize)


def make_balanced_perm(key, n, num_shards):
    """Permutation that sends exactly B_local/num_shards rows between every
    (src, dst) shard pair: shuffle within shards, exchange equal blocks,
    shuffle within shards again — the composition is the collector shuffle
    actually deployed (IID-simulation quality equals a uniform shuffle after
    two rounds, see tests)."""
    assert n % num_shards == 0
    b = n // num_shards
    assert b % num_shards == 0
    k1, k2, k3 = jax.random.split(key, 3)

    def shard_shuffle(key):
        keys = jax.random.split(key, num_shards)
        return jnp.concatenate([
            jax.random.permutation(keys[i], b) + i * b
            for i in range(num_shards)])

    p1 = shard_shuffle(k1)
    # block exchange: row j of shard i goes to shard (j mod S), position
    # determined by source
    blk = b // num_shards
    src = jnp.arange(n)
    shard = src // b
    pos = src % b
    dst_shard = pos // blk
    dst_pos = (pos % blk) + shard * blk
    p2 = dst_shard * b + dst_pos
    p3 = shard_shuffle(k3)
    # compose: out[i] = x[p1[p2[p3[i]]]]
    return p1[p2[p3]]


def group_fits_slabs(start, size, b):
    """Whether a contiguous flush group of ``size`` rows at ``start`` can
    be permuted without crossing a shard slab mid-group: it either covers
    whole ``b``-row slabs (balanced exchange) or lives entirely inside one
    (in-place shuffle, no exchange). The single predicate shared by the
    eager layout validator and the perm builder."""
    aligned = start % b == 0 and size % b == 0
    in_slab = start // b == (start + size - 1) // b
    return aligned, in_slab


def make_grouped_balanced_perm(key, n, num_shards, group_sizes):
    """Per-flush-group balanced permutations aligned to shard boundaries.

    ``group_sizes`` are contiguous row counts (summing to n) of the
    collector's flush groups (``collector.flush_group_sizes`` times the
    per-client rows). Rows never cross a group boundary — the sharded
    counterpart of ``collector.make_flush_perm`` — and within each group
    spanning S_g whole shards the permutation is a balanced exchange that
    routes exactly b/S_g rows between every shard pair of the group. A
    group contained in a single shard slab shuffles uniformly in place
    (no exchange). Requires every group to cover whole slabs or live
    inside one, and b divisible by S_g.

    Contract: ``key`` a PRNG key, ``n`` the pooled row count, and the
    returned ``(n,)`` permutation maps every row inside its own group —

    >>> import jax
    >>> p = make_grouped_balanced_perm(jax.random.PRNGKey(0), 16, 2,
    ...                                [8, 8])
    >>> bool((jnp.sort(p[:8]) == jnp.arange(8)).all())
    True
    """
    if len(group_sizes) <= 1:
        return make_balanced_perm(key, n, num_shards)
    b = n // num_shards
    parts, start = [], 0
    for f, size in enumerate(group_sizes):
        aligned, in_slab = group_fits_slabs(start, size, b)
        assert aligned or in_slab, (start, size, b)
        kf = jax.random.fold_in(key, f)
        if aligned and size // b > 1:
            sub = make_balanced_perm(kf, size, size // b)
        else:
            sub = jax.random.permutation(kf, size)
        parts.append(sub + start)
        start += size
    return jnp.concatenate(parts)


def grouped_perm_slack(n, num_shards, group_sizes):
    """Slack sizing the exchange buckets for a grouped balanced permutation:
    a group spanning S_g whole shards loads b/S_g rows on each of its shard
    pairs; groups inside a single slab keep all rows resident (self-pair
    load up to b). The buffer must hold the worst load. One global flush at
    b % S == 0 resolves to exactly 1.0, the drop-free balanced default."""
    b = n // num_shards
    return exact_pair_cap(n, num_shards, group_sizes) * num_shards / b


def exact_pair_cap(n, num_shards, group_sizes=None):
    """Exact worst (src, dst) bucket load of a (grouped) balanced
    permutation — deterministic by construction, so a plan built at this
    capacity is drop-free with ZERO slack padding (``may_drop=False``,
    the dense fast path). A group spanning S_g whole shards loads exactly
    b/S_g rows per pair inside the group; a group living inside one slab
    keeps all its rows resident (self-pair load b).

    >>> exact_pair_cap(64, 8)          # one global flush: b/S
    1
    >>> exact_pair_cap(64, 8, [32, 32])
    2
    """
    b = n // num_shards
    sizes = list(group_sizes) if group_sizes else [n]
    return max((b // (size // b)) if size % b == 0 else b
               for size in sizes)


def submesh_slice_size(n, n_shards, group_sizes):
    """Shards per owning slice when the grouped layout qualifies for the
    sub-mesh streaming exchange, else ``None``.

    Qualifies iff every flush group covers the SAME number ``S`` of whole
    ``b = n // n_shards``-row shard slabs (so contiguous groups partition
    the mesh axis into equal slices, group ``g`` owning shards
    ``[g*S, (g+1)*S)``) and ``b % S == 0`` (the balanced sub-permutation
    exchanges exactly ``b/S`` rows per in-slice shard pair — the dense,
    zero-slack capacity). One global flush qualifies trivially with the
    slice being the whole mesh.

    >>> submesh_slice_size(64, 8, [16, 16, 16, 16])   # S_g = 2 per group
    2
    >>> submesh_slice_size(64, 8, [64])               # one global flush
    8
    >>> submesh_slice_size(64, 8, [32, 16, 16]) is None  # unequal spans
    True
    """
    b = n // n_shards
    sizes = list(group_sizes) if group_sizes else [n]
    if any(size % b for size in sizes):
        return None                     # a group straddles a slab boundary
    spans = {size // b for size in sizes}
    if len(spans) != 1:
        return None                     # axis_index_groups need equal sizes
    slice_size = spans.pop()
    if b % slice_size or n_shards % slice_size:
        return None
    return slice_size


def submesh_axis_groups(n_shards, slice_size):
    """``axis_index_groups`` partitioning the mesh axis into contiguous
    ``slice_size``-shard slices — each flush group's ``all_to_all`` runs
    only within its owning slice."""
    return [list(range(j, j + slice_size))
            for j in range(0, n_shards, slice_size)]


def _np_balanced_perm(rng, n, num_shards):
    """Host-side replica of ``make_balanced_perm``'s structure (shard
    shuffles composed with the equal-block exchange) for load probing —
    same distribution, numpy-generated."""
    b = n // num_shards

    def shard_shuffle():
        return np.concatenate([rng.permutation(b) + i * b
                               for i in range(num_shards)])

    p1 = shard_shuffle()
    blk = b // num_shards
    src = np.arange(n)
    shard = src // b
    pos = src % b
    p2 = (pos // blk) * b + (pos % blk) + shard * blk
    p3 = shard_shuffle()
    return p1[p2[p3]]


@functools.lru_cache(maxsize=None)
def _balanced_stream_slack_cached(n, num_shards, span, probes, seed, margin):
    rng = np.random.default_rng(seed)
    worst = 0
    for _ in range(probes):
        perm = (_np_balanced_perm(rng, n, span) if span > 1
                else rng.permutation(n))
        worst = max(worst, max_pair_load(perm, num_shards))
    b = n // num_shards
    # never exceed the capacity-safe default slack = num_shards
    # (cap = b + 1 per pair holds ANY permutation of the group)
    return min((worst + margin) * num_shards / b, float(num_shards))


def balanced_stream_slack(n, num_shards, span, *, probes=16, seed=0,
                          margin=1):
    """Auto-size the streamed whole-mesh fallback's exchange slack for one
    BALANCED flush group by probing ``max_pair_load`` over sample draws of
    the group's actual permutation family: ``span`` is the number of
    original shard slabs the group covers — its grouped-balanced
    sub-permutation is a balanced exchange over ``span`` blocks
    (``make_grouped_balanced_perm``), measured here against the ``n //
    num_shards``-row FINE slabs the fallback re-shards the group into
    (``span <= 1`` groups shuffle uniformly in place). The bound is
    empirical — pair it with ``check_capacity=True`` — clamped at the old
    capacity-safe ``num_shards`` default so it can only shrink the buffer,
    and memoized like ``uniform_auto_slack`` so re-traces never re-probe."""
    return _balanced_stream_slack_cached(n, num_shards, span, probes, seed,
                                         margin)


@functools.lru_cache(maxsize=None)
def _uniform_auto_slack_cached(n, num_shards, group_sizes, probes, seed,
                               margin):
    rng = np.random.default_rng(seed)
    sizes = list(group_sizes) if group_sizes else [n]
    worst = 0
    for _ in range(probes):
        parts, start = [], 0
        for size in sizes:
            parts.append(rng.permutation(size) + start)
            start += size
        worst = max(worst, max_pair_load(np.concatenate(parts), num_shards))
    b = n // num_shards
    return (worst + margin) * num_shards / b


def uniform_auto_slack(n, num_shards, group_sizes=None, *, probes=16,
                       seed=0, margin=1):
    """Auto-size the exchange slack for paper-faithful uniform shuffles by
    probing ``max_pair_load`` over sample permutations (honouring flush
    groups when given) and padding by ``margin`` rows. The bound is
    empirical, not worst-case — pair it with ``check_capacity=True`` so an
    unlucky draw raises instead of silently dropping rows.

    The host-side probing is memoized on ``(n, num_shards, group_sizes,
    probes, seed, margin)``, so re-tracing a jitted epoch never re-runs
    the ``probes`` sample permutations."""
    key = tuple(group_sizes) if group_sizes is not None else None
    return _uniform_auto_slack_cached(n, num_shards, key, probes, seed,
                                      margin)


def axis_tuple(axis):
    """Collector mesh axis as a tuple of axis names: the 1-D mesh passes a
    bare string (``"data"``), the 2-D multi-host mesh a pod-major tuple
    (``("pod", "data")``) whose flattened index is the shard index."""
    return (axis,) if isinstance(axis, str) else tuple(axis)


def mesh_axis_size(mesh, axis):
    """Number of shards along ``axis`` of a mesh — the product of the named
    sizes when ``axis`` is a tuple (the flattened pod-major shard count of
    a 2-D ``("pod", "data")`` collector mesh)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for name in axis_tuple(axis):
        out *= sizes[name]
    return out


def pair_capacity(n, n_shards, slack):
    """Rows the exchange buffer holds per (src, dst) shard pair."""
    b = n // n_shards
    return int(b * slack) // n_shards + 1


def pair_load(perm, n_shards):
    """Host-side (src, dst) bucket-load matrix of a permutation.

    ``load[s, d]`` = rows that shard ``s`` must ship to shard ``d`` under
    ``out[i] = x[perm[i]]`` with both arrays row-sharded into ``n_shards``
    equal slabs."""
    perm = np.asarray(perm)
    n = perm.shape[0]
    assert n % n_shards == 0, (n, n_shards)
    b = n // n_shards
    dst = np.arange(n) // b          # destination shard of each output row
    src = perm // b                  # source shard of the row it pulls
    load = np.zeros((n_shards, n_shards), np.int64)
    np.add.at(load, (src, dst), 1)
    return load


def max_pair_load(perm, n_shards):
    """Worst bucket load — a perm is drop-free iff this <= pair_capacity."""
    return int(pair_load(perm, n_shards).max())


def assert_pair_capacity(perm, n_shards, *, slack):
    """Host-side guard: raise before launching an exchange that would drop
    rows."""
    n = np.asarray(perm).shape[0]
    cap = pair_capacity(n, n_shards, slack)
    worst = max_pair_load(perm, n_shards)
    if worst > cap:
        raise ValueError(
            f"collector exchange would drop rows: max (src, dst) load "
            f"{worst} exceeds capacity {cap} (n={n}, shards={n_shards}, "
            f"slack={slack}); raise slack or use make_balanced_perm")


def _raise_on_overflow(count):
    if int(count) > 0:
        raise RuntimeError(
            f"shuffle_shard_map dropped {int(count)} rows: per-pair bucket "
            f"capacity exceeded — raise slack or use make_balanced_perm")


# --------------------------------------------------------------------------
# route plans


@dataclasses.dataclass(frozen=True)
class RoutePlan:
    """Precomputed routing metadata of one exchange direction.

    Built once per (replicated) permutation and shared across every use of
    that direction — the forward exchange, the custom-VJP backward
    exchange, and the streaming collector's ``route_back``. Both exchange
    sides are pure row gathers driven by the plan:

      * ``send_idx``: ``(n_shards, n_shards * cap)`` int32 — on shard
        ``s``, flattened (destination shard, bucket slot) -> local source
        row. Slots no row occupies point at row 0; they are never read on
        the receive side, so no masking or zero-fill of the send buffer is
        needed.
      * ``recv_idx``: ``(n_shards, b)`` int32 — on shard ``d``, local
        output row -> flattened (source shard, bucket slot) of the
        received block. On slack-buffered plans (``may_drop=True``) a
        dropped row points at the appended zero pad row ``n_shards*cap``.
      * ``overflow``: replicated count of rows exceeding ``cap`` (the rows
        a ``check_capacity`` callback reports); ``None`` on dense plans,
        whose loads are deterministic.

    Static metadata: ``n`` (global rows), ``n_shards``, ``cap`` (bucket
    rows per shard pair), ``may_drop``. ``slice_size`` is ``None`` for a
    whole-mesh exchange; a sub-mesh plan (``build_submesh_route_plans``)
    sets it to the owning slice's shard count ``S`` and the collective
    runs under ``axis_index_groups`` of that width. ``dense`` means the
    send buffer has zero slack padding: the participating shard count
    times ``cap`` equals the ``b``-row slab, with drops impossible.
    """
    send_idx: jax.Array
    recv_idx: jax.Array
    overflow: Optional[jax.Array]
    n: int
    n_shards: int
    cap: int
    may_drop: bool
    slice_size: Optional[int] = None

    @property
    def dense(self):
        shards = self.slice_size or self.n_shards
        return (not self.may_drop
                and shards * self.cap == self.n // self.n_shards)


jax.tree_util.register_dataclass(
    RoutePlan, data_fields=["send_idx", "recv_idx", "overflow"],
    meta_fields=["n", "n_shards", "cap", "may_drop", "slice_size"])


def inverse_permutation_scatter(perm):
    """O(n) scatter-based inverse permutation: ``inv[perm[i]] = i``.

    Replaces the exchange path's ``argsort`` (O(n log n), and previously
    re-derived on every call, forward and backward)."""
    n = perm.shape[0]
    return jnp.zeros((n,), jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32))


def _build_one_plan(out_pos, n_shards, cap, may_drop):
    """Plan of the exchange whose source row ``g`` lands at global output
    position ``out_pos[g]`` (i.e. ``out_pos`` is the inverse of the
    permutation being applied). O(n * n_shards), no sorts."""
    n = out_pos.shape[0]
    b = n // n_shards
    g = jnp.arange(n, dtype=jnp.int32)
    src_shard = g // b
    local_row = g % b
    dest = (out_pos // b).astype(jnp.int32)
    # rank of row g within its (src_shard, dest) bucket, in ascending-g
    # order: a per-slab running count of destinations (one-hot cumsum) —
    # both exchange sides read the same rank, so any consistent order works
    oh = jax.nn.one_hot(dest.reshape(n_shards, b), n_shards,
                        dtype=jnp.int32)
    cum = jnp.cumsum(oh, axis=1)
    rank = (jnp.take_along_axis(
        cum, dest.reshape(n_shards, b, 1), axis=2) - 1).reshape(n)
    ok = rank < cap
    # overflowing rows go to an OOB slot and are DROPPED by the scatter —
    # they can never clobber an in-capacity row's slot
    slot = jnp.where(ok, dest * cap + rank, n_shards * cap)
    send_idx = jnp.zeros((n_shards, n_shards * cap), jnp.int32).at[
        src_shard, slot].set(local_row, mode="drop")
    out_local = jnp.where(ok, out_pos % b, b)
    init = (jnp.full((n_shards, b), n_shards * cap, jnp.int32)
            if may_drop else jnp.zeros((n_shards, b), jnp.int32))
    recv_idx = init.at[dest, out_local].set(src_shard * cap + rank,
                                            mode="drop")
    overflow = jnp.sum(~ok).astype(jnp.int32) if may_drop else None
    return RoutePlan(send_idx, recv_idx, overflow, int(n), n_shards,
                     int(cap), bool(may_drop))


def build_route_plan(perm, n_shards, *, cap, may_drop=True):
    """Forward-direction plan of ``out[i] = x[perm[i]]``.

    Contract: ``may_drop=False`` asserts the permutation's max (src, dst)
    pair load is <= ``cap`` (true by construction for (grouped-)balanced
    perms at ``exact_pair_cap``); routing under a violating perm is
    undefined — keep ``may_drop=True`` (and ``check_capacity``) for any
    permutation whose loads are not deterministic."""
    perm = perm.astype(jnp.int32)
    return _build_one_plan(inverse_permutation_scatter(perm), n_shards,
                           cap, may_drop)


def build_route_plans(perm, n_shards, *, cap, may_drop=True):
    """(forward, backward) plans of a permutation, sharing one O(n)
    inverse: the backward exchange applies ``argsort(perm)``, whose
    inverse is ``perm`` itself — so BOTH plans come from the same two
    arrays and the gradient de-shuffle re-derives nothing. The bucket-load
    matrix of the inverse permutation is the transpose of the forward
    one, so one ``cap`` covers both directions."""
    perm = perm.astype(jnp.int32)
    inv = inverse_permutation_scatter(perm)
    fwd = _build_one_plan(inv, n_shards, cap, may_drop)
    bwd = _build_one_plan(perm, n_shards, cap, may_drop)
    return fwd, bwd


def _embed_slice_plan(plan, slice_index, n_shards):
    """Embed a slice-local dense plan (built over ``S = plan.n_shards``
    shards) into full-mesh-shaped ``(n_shards, b)`` index arrays at rows
    ``[slice_index * S, (slice_index + 1) * S)``. Shards outside the slice
    keep zero indices: within their own slice's collective they gather and
    scatter garbage whose output rows the caller masks off."""
    slice_size = plan.n_shards
    b = plan.recv_idx.shape[1]
    j0 = slice_index * slice_size
    embed = lambda idx: jnp.zeros((n_shards, b), jnp.int32).at[
        j0:j0 + slice_size].set(idx)
    return RoutePlan(embed(plan.send_idx), embed(plan.recv_idx), None,
                     n_shards * b, n_shards, plan.cap, False,
                     slice_size=slice_size)


def build_submesh_route_plans(sub_perm, slice_index, n_shards, slice_size):
    """(forward, backward) DENSE plans of flush group ``slice_index``'s
    sub-permutation, routed only over the group's owning ``slice_size``-
    shard slice (sub-mesh streaming — the layout must satisfy
    ``submesh_slice_size``).

    ``sub_perm`` is the group's ``(n_g,)`` permutation in group-local
    coordinates (``n_g = slice_size * b``). The slice-local exchange is
    built exactly like the whole-mesh dense path — exact per-pair capacity
    ``b / slice_size``, ``may_drop=False``, no overflow counter, no pad
    row — then embedded into full-mesh-shaped index arrays so the exchange
    runs as one pool-width shard_map whose collective carries
    ``axis_index_groups`` of the slice width. Both plans share one O(n_g)
    scatter inverse, exactly like ``build_route_plans``."""
    sub_perm = sub_perm.astype(jnp.int32)
    n_g = sub_perm.shape[0]
    b = n_g // slice_size
    cap = b // slice_size
    inv = inverse_permutation_scatter(sub_perm)
    fwd = _build_one_plan(inv, slice_size, cap, False)
    bwd = _build_one_plan(sub_perm, slice_size, cap, False)
    return (_embed_slice_plan(fwd, slice_index, n_shards),
            _embed_slice_plan(bwd, slice_index, n_shards))


# --------------------------------------------------------------------------
# plan-driven exchange: gather -> ONE all_to_all -> gather


def _shard_map_maybe_norep(local, *, mesh, in_specs, out_specs, norep):
    shard_map = get_shard_map()
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if norep:
        # pallas_call has no replication rule; the kernel only touches
        # per-shard rows so skipping the check is sound. The flag was
        # renamed check_rep -> check_vma across jax versions.
        try:
            return shard_map(local, **kwargs, check_rep=False)
        except TypeError:
            return shard_map(local, **kwargs, check_vma=False)
    return shard_map(local, **kwargs)


def _gather_rows(x, idx, *, use_kernel, bucket_shape=None):
    """Row gather ``x[idx]``, optionally through the fused Pallas kernels:
    ``bucket_shape=(S, cap)`` routes through the two-level ``bucket_permute``
    (send side), ``None`` through the flat ``unbucket_permute`` mirror
    (receive side)."""
    if use_kernel and jnp.issubdtype(x.dtype, jnp.floating):
        from repro.kernels.collector_permute.ops import (bucket_permute,
                                                         unbucket_permute)
        interpret = jax.default_backend() != "tpu"
        if bucket_shape is not None:
            return bucket_permute(x, idx.reshape(bucket_shape),
                                  interpret=interpret)
        return unbucket_permute(x, idx, interpret=interpret)
    return x[idx]


def _resolve_wire(x_dtype, wire_dtype):
    """Effective wire dtype name for one payload, or ``None`` to ship it
    as-is: no wire dtype requested, a NON-FLOATING payload (the label pool
    rides the same plans — int rows never quantize, mirroring the kernel
    gate), or a wire dtype the payload already is in (the bf16 compute
    path ships bf16 natively; re-casting would be a no-op)."""
    wire = resolve_wire_dtype(wire_dtype)
    if wire is None or not jnp.issubdtype(x_dtype, jnp.floating):
        return None
    if jnp.dtype(WIRE_DTYPES[wire]) == jnp.dtype(x_dtype):
        return None
    return wire


def _quant_send_payload(x_loc, send_idx, S, cap, wire, use_kernel):
    """Send side of a quantized exchange: fused quantize-gather of the
    local rows into bucket order, with each row's f32 scale bitcast into
    ``SCALE_LANES`` trailing one-byte columns — ``(S*cap, d + LANES)``,
    ONE wire-dtype operand for the ``all_to_all`` (the scale sidecar
    never becomes a second collective)."""
    if use_kernel:
        from repro.kernels.quant_permute.ops import quant_bucket_permute
        q, scales = quant_bucket_permute(
            x_loc, send_idx.reshape(S, cap), wire_dtype=wire,
            interpret=jax.default_backend() != "tpu")
    else:
        from repro.kernels.quant_permute.ref import quant_bucket_permute_ref
        x2 = x_loc.reshape(x_loc.shape[0], -1)
        q, scales = quant_bucket_permute_ref(x2, send_idx, wire)
    return jnp.concatenate([q, pack_scales(scales, wire)], axis=1)


def _dequant_recv_payload(flat, recv_idx, wire, out_dtype, feat_shape,
                          use_kernel):
    """Receive side: split the flat ``(R, d + LANES)`` wire block back
    into rows and scales, and fused dequantize-gather into output order
    in the compute dtype. The slack pad row is all-zero — its packed
    scale unpacks to 0.0, so dropped rows dequantize to exact zeros."""
    d = flat.shape[1] - SCALE_LANES
    q, lanes = flat[:, :d], flat[:, d:]
    scales = unpack_scales(lanes)
    if use_kernel:
        from repro.kernels.quant_permute.ops import dequant_unbucket_permute
        out2 = dequant_unbucket_permute(
            q, scales, recv_idx, out_dtype=jnp.dtype(out_dtype),
            interpret=jax.default_backend() != "tpu")
    else:
        from repro.kernels.quant_permute.ref import (
            dequant_unbucket_permute_ref)
        out2 = dequant_unbucket_permute_ref(q, scales, recv_idx, out_dtype)
    return out2.reshape((recv_idx.shape[0],) + feat_shape)


def _plan_exchange_spec(plan):
    """(bucket shard count, cap) shaping a plan's send/receive buckets:
    whole-mesh plans exchange ``(n_shards, cap)`` blocks, sub-mesh plans
    ``(slice_size, cap)`` blocks confined to the owning slice."""
    if plan.slice_size is None:
        return plan.n_shards, plan.cap
    return plan.slice_size, plan.cap


def _plan_collective(plan, mesh, axis):
    """(collective axis name(s), axis_index_groups) of a plan's
    ``all_to_all`` on ``mesh``.

    Whole-mesh plans run over the full collector axis — the bare axis name
    on a 1-D mesh, the pod-major name tuple on a 2-D ``("pod", "data")``
    mesh (participants flatten pod-major, matching the
    ``P(("pod", "data"))`` dim-0 sharding, so the flattened shard index IS
    the plan's shard index). Sub-mesh plans confine each flush group's
    collective to its owning contiguous slice:

      * 1-D mesh: ``axis_index_groups`` partitioning the whole axis into
        ``slice_size``-shard slices;
      * 2-D mesh, slice within a pod (``per_pod % slice_size == 0``): the
        collective runs over the INNER (data) axis only, with
        ``axis_index_groups`` partitioning ``[0, per_pod)`` — every pod
        exchanges its own slices simultaneously, no cross-pod traffic;
      * a slice straddling pods has no grouped-collective expression and
        must be disqualified upstream (``StreamingAllToAll.submesh_slices``
        gates it to the whole-mesh fallback) — reaching here raises.
    """
    names = axis_tuple(axis)
    if plan.slice_size is None or plan.slice_size == plan.n_shards:
        coll = names[0] if len(names) == 1 else names
        return coll, None
    if len(names) == 1:
        return names[0], submesh_axis_groups(plan.n_shards, plan.slice_size)
    inner = mesh_axis_size(mesh, names[-1])
    if inner % plan.slice_size:
        raise ValueError(
            f"sub-mesh slice of {plan.slice_size} shards straddles the "
            f"pod boundary (per-pod axis {names[-1]!r} holds {inner} "
            f"shards) — the layout gate must route this group over the "
            f"whole-mesh fallback")
    return names[-1], submesh_axis_groups(inner, plan.slice_size)


def plan_payload_bytes(plan, row_elems, itemsize, *, wire_dtype=None):
    """Wire bytes of ONE collective under a plan: every one of the
    ``n_shards`` participating shards ships its ``(S, cap)`` bucket block
    — ``S = slice_size`` under sub-mesh ``axis_index_groups``, else the
    whole axis — of ``row_elems``-element rows at ``itemsize`` bytes per
    element. Shapes are dtype-independent, so a bf16 exchange is exactly
    half the f32 bytes at a matched plan.

    ``wire_dtype`` overrides ``itemsize`` with the wire format's exact
    accounting: rows ship at the wire itemsize, and quantized wires add
    ``SCALE_BYTES`` per row (the bitcast f32 scale lanes packed into the
    payload operand) — int8 rows cost ``row_elems + 4`` bytes against
    f32's ``4 * row_elems``."""
    S, cap = _plan_exchange_spec(plan)
    rows = plan.n_shards * S * cap
    wire = resolve_wire_dtype(wire_dtype)
    if wire is None:
        return rows * row_elems * itemsize
    row_bytes = row_elems * wire_itemsize(wire)
    if is_quantized(wire):
        row_bytes += SCALE_BYTES
    return rows * row_bytes


def plan_exchange(x, plan, *, mesh, axis="data", use_kernel=False,
                  check_capacity=False, wire_dtype=None):
    """One full exchange under a route plan: bucket-gather this shard's
    rows into send layout, ship them with ONE ``all_to_all``, and gather
    the received block into output order. Not differentiable on its own —
    ``plan_shuffle`` supplies the VJP from the backward plan, and the
    streaming collector routes gradients explicitly.

    Deliberately NOT composed from ``plan_exchange_issue`` +
    ``plan_exchange_complete``: the sync exchange keeps both gathers and
    the collective in one shard_map region (one SPMD program, no sharded
    bucket intermediate crossing a shard_map boundary); the split halves
    exist so the streaming pipeline can put compute between them.
    tests/test_streaming.py pins the composition row-for-row equal.

    A sub-mesh plan (``plan.slice_size = S``) exchanges ``(S, cap)``
    buckets under ``axis_index_groups`` of the slice width instead —
    on a pool-width input only the owning slice's output rows are
    meaningful; the caller masks the rest. ``axis`` may be the pod-major
    name tuple of a 2-D mesh (``_plan_collective`` picks the collective
    scope).

    ``wire_dtype`` narrows the payload that crosses the collective (see
    ``core.wire``): bf16 is a cast around the unchanged exchange;
    int8/fp8 swap the two gathers for the fused quantize/dequantize
    gathers, with the per-row f32 scales bitcast into ``SCALE_LANES``
    trailing payload columns — still exactly ONE ``all_to_all``, its
    operand in the wire dtype. Non-floating payloads (the label pool)
    ship as-is regardless."""
    S, cap = _plan_exchange_spec(plan)
    coll_axis, groups = _plan_collective(plan, mesh, axis)
    check = check_capacity and plan.overflow is not None
    wire = _resolve_wire(x.dtype, wire_dtype)
    quant = wire is not None and is_quantized(wire)
    out_dtype, feat_shape = x.dtype, x.shape[1:]

    def local(x_loc, send_idx, recv_idx, *overflow):
        if check:
            # raised inside EVERY shard's program, so all collective
            # participants abort together instead of deadlocking the
            # all_to_all rendezvous on the survivors
            jax.debug.callback(_raise_on_overflow, overflow[0])
        if quant:
            payload = _quant_send_payload(x_loc, send_idx[0], S, cap,
                                          wire, use_kernel)
            recv = jax.lax.all_to_all(
                payload.reshape((S, cap, payload.shape[1])), coll_axis,
                0, 0, tiled=False, axis_index_groups=groups)
            flat = recv.reshape((S * cap, payload.shape[1]))
            if plan.may_drop:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)])
            return _dequant_recv_payload(flat, recv_idx[0], wire,
                                         out_dtype, feat_shape, use_kernel)
        x_w = x_loc.astype(WIRE_DTYPES[wire]) if wire else x_loc
        bucket = _gather_rows(x_w, send_idx[0], use_kernel=use_kernel,
                              bucket_shape=(S, cap))
        recv = jax.lax.all_to_all(
            bucket.reshape((S, cap) + x_loc.shape[1:]), coll_axis, 0, 0,
            tiled=False, axis_index_groups=groups)
        flat = recv.reshape((S * cap,) + x_loc.shape[1:])
        if plan.may_drop:
            flat = jnp.concatenate(
                [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)])
        out = _gather_rows(flat, recv_idx[0], use_kernel=use_kernel)
        return out.astype(out_dtype) if wire else out

    ex = _shard_map_maybe_norep(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)) + ((P(),) if check else ()),
        out_specs=P(axis), norep=use_kernel)
    args = (x, plan.send_idx, plan.recv_idx)
    return ex(*args + ((plan.overflow,) if check else ()))


def plan_exchange_issue(x, plan, *, mesh, axis="data", use_kernel=False,
                        check_capacity=False, wire_dtype=None):
    """First (issue) half of the split exchange: bucket-gather this shard's
    rows by destination and hand them to ``all_to_all``.

    Returns the in-flight buffer slot — ``(recv, plan, wire_ctx)`` where
    ``recv`` is the received bucket block (leading dim sharded over
    ``axis``) and ``wire_ctx`` is ``None`` or the static ``(wire name,
    compute dtype, feature shape)`` the completion side needs to undo the
    wire format — under a quantized wire ``recv`` is the packed
    wire-dtype block (rows + bitcast scale lanes), so neither the compute
    dtype nor the feature shape is recoverable from the array itself.
    The payload stays ONE array: positions and validity never travel over
    the wire, the completion side derives placement from the plan.
    Nothing about the slot depends on later compute, so a scheduler is
    free to overlap the collective with whatever runs between ``issue``
    and ``complete`` — the hook the double-buffered streaming collector
    pipelines client forwards into. A sub-mesh plan's collective runs
    under ``axis_index_groups`` of the owning slice's width."""
    S, cap = _plan_exchange_spec(plan)
    coll_axis, groups = _plan_collective(plan, mesh, axis)
    check = check_capacity and plan.overflow is not None
    wire = _resolve_wire(x.dtype, wire_dtype)
    quant = wire is not None and is_quantized(wire)
    ctx = None if wire is None else (wire, x.dtype, x.shape[1:])

    def local(x_loc, send_idx, *overflow):
        if check:
            jax.debug.callback(_raise_on_overflow, overflow[0])
        if quant:
            payload = _quant_send_payload(x_loc, send_idx[0], S, cap,
                                          wire, use_kernel)
            return jax.lax.all_to_all(
                payload.reshape((S, cap, payload.shape[1])), coll_axis,
                0, 0, tiled=False, axis_index_groups=groups)
        x_w = x_loc.astype(WIRE_DTYPES[wire]) if wire else x_loc
        bucket = _gather_rows(x_w, send_idx[0], use_kernel=use_kernel,
                              bucket_shape=(S, cap))
        return jax.lax.all_to_all(
            bucket.reshape((S, cap) + x_loc.shape[1:]), coll_axis, 0, 0,
            tiled=False, axis_index_groups=groups)

    issue = _shard_map_maybe_norep(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis)) + ((P(),) if check else ()),
        out_specs=P(axis), norep=use_kernel)
    return issue(*(x, plan.send_idx)
                 + ((plan.overflow,) if check else ())), plan, ctx


def plan_exchange_complete(slot, *, mesh, axis="data", use_kernel=False):
    """Second (complete) half: gather the received bucket block of a
    ``plan_exchange_issue`` slot into local output order, undoing the
    slot's wire format (cast back, or unpack scales + fused dequantize
    gather) into the compute dtype it was issued from."""
    recv, plan, ctx = slot
    S, cap = _plan_exchange_spec(plan)
    wire = None if ctx is None else ctx[0]
    quant = wire is not None and is_quantized(wire)

    def local(recv, recv_idx):
        flat = recv.reshape((S * cap,) + recv.shape[2:])
        if plan.may_drop:
            flat = jnp.concatenate(
                [flat, jnp.zeros((1,) + flat.shape[1:], flat.dtype)])
        if quant:
            _, out_dtype, feat_shape = ctx
            return _dequant_recv_payload(flat, recv_idx[0], wire,
                                         out_dtype, feat_shape, use_kernel)
        out = _gather_rows(flat, recv_idx[0], use_kernel=use_kernel)
        return out.astype(ctx[1]) if wire else out

    complete = _shard_map_maybe_norep(
        local, mesh=mesh, in_specs=(P(axis), P(axis)),
        out_specs=P(axis), norep=use_kernel)
    return complete(recv, plan.recv_idx)


def plan_shuffle(x, plans, *, mesh, axis="data", use_kernel=False,
                 check_capacity=False, wire_dtype=None, wire_dtype_bwd=None):
    """Differentiable plan exchange: ``plans`` is the ``(forward,
    backward)`` pair from ``build_route_plans``. The registered VJP is the
    plan exchange with the BACKWARD plan (Algorithm 1's de-shuffle) —
    carried as the custom-VJP residual, so the backward pass issues one
    more ``all_to_all`` and re-derives no routing metadata. The VJP is
    registered at this level — not inside the shard_map body — because
    per-shard (data-dependent) custom_vjp residuals do not survive
    shard_map transposition with replication checking off.

    ``wire_dtype`` narrows the forward payload; gradients are
    STRAIGHT-THROUGH w.r.t. the dequantized values — the backward
    exchange routes cotangents of what the receiver actually saw, and is
    itself exact unless ``wire_dtype_bwd`` opts the gradient rows into a
    narrow wire too (the two legs are independent knobs because gradient
    rows are usually the more quantization-sensitive leg)."""
    impl = functools.partial(plan_exchange, mesh=mesh, axis=axis,
                             use_kernel=use_kernel)

    @jax.custom_vjp
    def shuf(x, fwd_plan, bwd_plan):
        return impl(x, fwd_plan, check_capacity=check_capacity,
                    wire_dtype=wire_dtype)

    def shuf_fwd(x, fwd_plan, bwd_plan):
        return impl(x, fwd_plan, check_capacity=check_capacity,
                    wire_dtype=wire_dtype), bwd_plan

    def shuf_bwd(bwd_plan, g):
        # exact for drop-free plans; under bucket overflow the forward
        # already lost rows (see check_capacity), so exactness is moot
        return impl(g, bwd_plan, wire_dtype=wire_dtype_bwd), None, None

    shuf.defvjp(shuf_fwd, shuf_bwd)
    return shuf(x, *plans)


# --------------------------------------------------------------------------
# perm-level entry points (plan built on the fly)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "slack", "use_kernel", "check_capacity"))
def shuffle_shard_map(x, perm, *, mesh, axis="data", slack=2.0,
                      use_kernel=False, check_capacity=False):
    """x: (N, ...) sharded over ``axis`` on dim 0; perm: (N,) replicated.

    Returns x[perm] with the same sharding, via one explicit all_to_all.

    Convenience wrapper over the plan machinery for callers holding a bare
    permutation: builds the (forward, backward) plans at the slack-derived
    capacity and applies ``plan_shuffle``. The round engine builds plans
    itself (``round.MeshAllToAll.prepare``) so one plan pair serves the
    label permute, the activation permute, and the backward exchange.

    ``use_kernel`` routes the local bucket gathers through the Pallas
    ``bucket_permute``/``unbucket_permute`` kernels (interpret-mode
    off-TPU); ``check_capacity`` adds an in-graph ``jax.debug.callback``
    that raises if any (src, dst) bucket overflows instead of zero-filling
    the overflowing rows."""
    n = x.shape[0]
    n_shards = mesh_axis_size(mesh, axis)
    cap = pair_capacity(n, n_shards, slack)
    plans = build_route_plans(perm, n_shards, cap=cap, may_drop=True)
    return plan_shuffle(x, plans, mesh=mesh, axis=axis,
                        use_kernel=use_kernel,
                        check_capacity=check_capacity)


def exchange_issue(x, perm, *, mesh, axis="data", slack=2.0,
                   use_kernel=False, check_capacity=False):
    """Perm-level convenience for ``plan_exchange_issue``: builds the
    forward plan at the slack-derived capacity and issues the exchange.
    Returns the in-flight ``(recv, plan, wire_ctx)`` slot."""
    n = x.shape[0]
    n_shards = mesh_axis_size(mesh, axis)
    cap = pair_capacity(n, n_shards, slack)
    plan = build_route_plan(perm, n_shards, cap=cap, may_drop=True)
    return plan_exchange_issue(x, plan, mesh=mesh, axis=axis,
                               use_kernel=use_kernel,
                               check_capacity=check_capacity)


def exchange_complete(slot, n, *, mesh, axis="data"):
    """Perm-level convenience for ``plan_exchange_complete``; ``n`` is the
    global row count of the shuffled array (checked against the slot's
    plan). ``exchange_complete(exchange_issue(x, perm, ...), x.shape[0],
    ...)`` equals ``shuffle_shard_map(x, perm, ...)`` row for row."""
    _, plan, _ = slot
    assert plan.n == n, (plan.n, n)
    return plan_exchange_complete(slot, mesh=mesh, axis=axis)
