"""Explicit-collective distributed collector (shard_map + all_to_all).

`collector.distributed_shuffle` lets XLA choose the collectives for the
global permutation gather. This module is the paper-faithful explicit
schedule — Algorithm 1's collect -> shuffle -> scatter written as
`shard_map` with `jax.lax.all_to_all`:

  1. every data shard (client group) holds a (B_local, ...) slab of smashed
     data;
  2. the permutation is decomposed into (destination shard, destination row)
     pairs; rows are bucketed by destination shard locally;
  3. one `all_to_all` exchanges the buckets;
  4. each shard locally orders its received rows.

The same function with the inverse permutation is the de-shuffle, so the
gradient routing of Algorithm 1 is `shuffle_shard_map(g, inverse_permutation
(perm), ...)` — and because every step is jax-native, autodiff through the
forward shuffle produces exactly that (tested in tests/test_collector_dist).

Capacity note: a random permutation may route more rows from one source
shard to one destination shard than B_local; the exchange therefore uses a
per-pair capacity buffer of ``cap = ceil(B_local * slack)`` with validity
masks (drop-free for any permutation when ``slack`` covers the worst case;
``slack=1.0`` + assertion covers the common uniform case). For production
the collector uses balanced block permutations (``make_balanced_perm``)
that are drop-free at cap == B_local / n_shards by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def make_balanced_perm(key, n, num_shards):
    """Permutation that sends exactly B_local/num_shards rows between every
    (src, dst) shard pair: shuffle within shards, exchange equal blocks,
    shuffle within shards again — the composition is the collector shuffle
    actually deployed (IID-simulation quality equals a uniform shuffle after
    two rounds, see tests)."""
    assert n % num_shards == 0
    b = n // num_shards
    assert b % num_shards == 0
    k1, k2, k3 = jax.random.split(key, 3)

    def shard_shuffle(key):
        keys = jax.random.split(key, num_shards)
        return jnp.concatenate([
            jax.random.permutation(keys[i], b) + i * b
            for i in range(num_shards)])

    p1 = shard_shuffle(k1)
    # block exchange: row j of shard i goes to shard (j mod S), position
    # determined by source
    blk = b // num_shards
    src = jnp.arange(n)
    shard = src // b
    pos = src % b
    dst_shard = pos // blk
    dst_pos = (pos % blk) + shard * blk
    p2 = dst_shard * b + dst_pos
    p3 = shard_shuffle(k3)
    # compose: out[i] = x[p1[p2[p3[i]]]]
    return p1[p2[p3]]


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "slack"))
def shuffle_shard_map(x, perm, *, mesh, axis="data", slack=2.0):
    """x: (N, ...) sharded over ``axis`` on dim 0; perm: (N,) replicated.

    Returns x[perm] with the same sharding, via an explicit all_to_all.
    """
    n = x.shape[0]
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    b = n // n_shards
    cap = int(b * slack) // n_shards + 1

    def local(x_loc, perm):
        # this shard's rows of the OUTPUT: out[i] = x[perm[i]]
        sid = jax.lax.axis_index(axis)
        # which global rows do I need, and who owns them
        my_out = jnp.arange(b) + sid * b
        src_rows = perm[my_out]                       # (b,)
        # conversely: which of MY rows does each shard need?
        # shard s needs my row r if perm[s*b + j] == sid*b + r for some j.
        # build send buckets: for each destination shard, up to cap rows.
        inv = jnp.argsort(perm)                       # inv[g] = output pos
        my_rows_global = jnp.arange(b) + sid * b
        out_pos = inv[my_rows_global]                 # where my rows go
        dest = out_pos // b                           # destination shard
        # rank of each of my rows within its destination bucket
        order = jnp.argsort(dest)
        dsorted = dest[order]
        first = jnp.searchsorted(dsorted, dsorted, side="left")
        rank = jnp.arange(b) - first
        send = jnp.zeros((n_shards, cap) + x_loc.shape[1:], x_loc.dtype)
        send_pos = jnp.zeros((n_shards, cap), jnp.int32)
        slot_d = dsorted
        slot_r = jnp.minimum(rank, cap - 1)
        rows_sorted = x_loc[order % b]
        send = send.at[slot_d, slot_r].set(rows_sorted)
        send_pos = send_pos.at[slot_d, slot_r].set(out_pos[order])
        valid = jnp.zeros((n_shards, cap), bool).at[slot_d, slot_r].set(
            rank < cap)
        # 3. exchange buckets
        recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        recv_pos = jax.lax.all_to_all(send_pos, axis, 0, 0, tiled=False)
        recv_valid = jax.lax.all_to_all(valid, axis, 0, 0, tiled=False)
        # 4. place received rows at their local output offsets
        flat = recv.reshape((n_shards * cap,) + x_loc.shape[1:])
        fpos = recv_pos.reshape(-1) - sid * b
        fval = recv_valid.reshape(-1)
        fpos = jnp.where(fval, fpos, b)               # dropped -> OOB
        out = jnp.zeros((b,) + x_loc.shape[1:], x_loc.dtype)
        out = out.at[fpos].set(flat, mode="drop")
        return out

    shuf = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis))
    return shuf(x, perm)
