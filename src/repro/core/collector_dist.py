"""Explicit-collective distributed collector (shard_map + all_to_all).

`collector.distributed_shuffle` lets XLA choose the collectives for the
global permutation gather. This module is the paper-faithful explicit
schedule — Algorithm 1's collect -> shuffle -> scatter written as
`shard_map` with `jax.lax.all_to_all`:

  1. every data shard (client group) holds a (B_local, ...) slab of smashed
     data;
  2. the permutation is decomposed into (destination shard, destination row)
     pairs; rows are bucketed by destination shard locally;
  3. one `all_to_all` exchanges the buckets;
  4. each shard locally orders its received rows.

The same function with the inverse permutation is the de-shuffle, so the
gradient routing of Algorithm 1 is `shuffle_shard_map(g, inverse_permutation
(perm), ...)` — and because every step is jax-native, autodiff through the
forward shuffle produces exactly that (tested in tests/test_collector_dist).

Capacity note: a random permutation may route more rows from one source
shard to one destination shard than the bucket holds; the exchange uses a
per-pair capacity buffer of ``cap = int(B_local * slack) // n_shards + 1``
with validity masks. Overflowing rows are SILENTLY DROPPED (zeros in the
output) unless checked:

  * ``max_pair_load(perm, n_shards)`` — host-side: the worst (src, dst)
    bucket load of a permutation; compare against ``pair_capacity``.
  * ``assert_pair_capacity(perm, ...)`` — host-side hard failure.
  * ``shuffle_shard_map(..., check_capacity=True)`` — in-graph
    ``jax.debug.callback`` that raises from inside the jitted program.

For production the collector uses balanced block permutations
(``make_balanced_perm``) that are drop-free at ``slack=1.0`` by
construction (exactly B_local/n_shards rows per pair).

Streaming (double-buffered) collector: the exchange is also exposed as
two halves so a software pipeline can put client compute between them —
``exchange_issue`` buckets a slab's rows by destination shard and hands
them to ``all_to_all`` (the in-flight buffer slot), ``exchange_complete``
places the received rows at their local output offsets. The composition
is exactly ``shuffle_shard_map`` (same bucketing code), and the whole
shuffle keeps the inverse-permutation custom VJP: the backward pass is
one more issue/complete exchange with ``argsort(perm)``.

Shape/layout contract (all entry points):

  * ``x``: ``(N, ...)`` with dim 0 sharded into ``n_shards`` equal
    ``b = N // n_shards``-row slabs over the mesh ``axis``;
  * ``perm``: ``(N,)`` int, replicated; output row ``i`` is ``x[perm[i]]``;
  * slack/capacity: each (src, dst) shard pair exchanges at most
    ``pair_capacity(N, n_shards, slack)`` rows —

    >>> pair_capacity(64, 8, 1.0)   # balanced: exactly b/S rows per pair
    2
    >>> grouped_perm_slack(64, 8, [64])   # one global balanced flush
    1.0
    >>> int(pair_load(np.arange(8), 4).max())   # identity perm: diagonal
    2
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels._compat import get_shard_map


def make_balanced_perm(key, n, num_shards):
    """Permutation that sends exactly B_local/num_shards rows between every
    (src, dst) shard pair: shuffle within shards, exchange equal blocks,
    shuffle within shards again — the composition is the collector shuffle
    actually deployed (IID-simulation quality equals a uniform shuffle after
    two rounds, see tests)."""
    assert n % num_shards == 0
    b = n // num_shards
    assert b % num_shards == 0
    k1, k2, k3 = jax.random.split(key, 3)

    def shard_shuffle(key):
        keys = jax.random.split(key, num_shards)
        return jnp.concatenate([
            jax.random.permutation(keys[i], b) + i * b
            for i in range(num_shards)])

    p1 = shard_shuffle(k1)
    # block exchange: row j of shard i goes to shard (j mod S), position
    # determined by source
    blk = b // num_shards
    src = jnp.arange(n)
    shard = src // b
    pos = src % b
    dst_shard = pos // blk
    dst_pos = (pos % blk) + shard * blk
    p2 = dst_shard * b + dst_pos
    p3 = shard_shuffle(k3)
    # compose: out[i] = x[p1[p2[p3[i]]]]
    return p1[p2[p3]]


def group_fits_slabs(start, size, b):
    """Whether a contiguous flush group of ``size`` rows at ``start`` can
    be permuted without crossing a shard slab mid-group: it either covers
    whole ``b``-row slabs (balanced exchange) or lives entirely inside one
    (in-place shuffle, no exchange). The single predicate shared by the
    eager layout validator and the perm builder."""
    aligned = start % b == 0 and size % b == 0
    in_slab = start // b == (start + size - 1) // b
    return aligned, in_slab


def make_grouped_balanced_perm(key, n, num_shards, group_sizes):
    """Per-flush-group balanced permutations aligned to shard boundaries.

    ``group_sizes`` are contiguous row counts (summing to n) of the
    collector's flush groups (``collector.flush_group_sizes`` times the
    per-client rows). Rows never cross a group boundary — the sharded
    counterpart of ``collector.make_flush_perm`` — and within each group
    spanning S_g whole shards the permutation is a balanced exchange that
    routes exactly b/S_g rows between every shard pair of the group. A
    group contained in a single shard slab shuffles uniformly in place
    (no exchange). Requires every group to cover whole slabs or live
    inside one, and b divisible by S_g.

    Contract: ``key`` a PRNG key, ``n`` the pooled row count, and the
    returned ``(n,)`` permutation maps every row inside its own group —

    >>> import jax
    >>> p = make_grouped_balanced_perm(jax.random.PRNGKey(0), 16, 2,
    ...                                [8, 8])
    >>> bool((jnp.sort(p[:8]) == jnp.arange(8)).all())
    True
    """
    if len(group_sizes) <= 1:
        return make_balanced_perm(key, n, num_shards)
    b = n // num_shards
    parts, start = [], 0
    for f, size in enumerate(group_sizes):
        aligned, in_slab = group_fits_slabs(start, size, b)
        assert aligned or in_slab, (start, size, b)
        kf = jax.random.fold_in(key, f)
        if aligned and size // b > 1:
            sub = make_balanced_perm(kf, size, size // b)
        else:
            sub = jax.random.permutation(kf, size)
        parts.append(sub + start)
        start += size
    return jnp.concatenate(parts)


def grouped_perm_slack(n, num_shards, group_sizes):
    """Slack sizing the exchange buckets for a grouped balanced permutation:
    a group spanning S_g whole shards loads b/S_g rows on each of its shard
    pairs; groups inside a single slab keep all rows resident (self-pair
    load up to b). The buffer must hold the worst load. One global flush at
    b % S == 0 resolves to exactly 1.0, the drop-free balanced default."""
    b = n // num_shards
    req = max((b // (size // b)) if size % b == 0 else b
              for size in group_sizes)
    return req * num_shards / b


def uniform_auto_slack(n, num_shards, group_sizes=None, *, probes=16,
                       seed=0, margin=1):
    """Auto-size the exchange slack for paper-faithful uniform shuffles by
    probing ``max_pair_load`` over sample permutations (honouring flush
    groups when given) and padding by ``margin`` rows. The bound is
    empirical, not worst-case — pair it with ``check_capacity=True`` so an
    unlucky draw raises instead of silently dropping rows."""
    rng = np.random.default_rng(seed)
    sizes = list(group_sizes) if group_sizes else [n]
    worst = 0
    for _ in range(probes):
        parts, start = [], 0
        for size in sizes:
            parts.append(rng.permutation(size) + start)
            start += size
        worst = max(worst, max_pair_load(np.concatenate(parts), num_shards))
    b = n // num_shards
    return (worst + margin) * num_shards / b


def mesh_axis_size(mesh, axis):
    """Number of shards along ``axis`` of a mesh."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


def pair_capacity(n, n_shards, slack):
    """Rows the exchange buffer holds per (src, dst) shard pair."""
    b = n // n_shards
    return int(b * slack) // n_shards + 1


def pair_load(perm, n_shards):
    """Host-side (src, dst) bucket-load matrix of a permutation.

    ``load[s, d]`` = rows that shard ``s`` must ship to shard ``d`` under
    ``out[i] = x[perm[i]]`` with both arrays row-sharded into ``n_shards``
    equal slabs."""
    perm = np.asarray(perm)
    n = perm.shape[0]
    assert n % n_shards == 0, (n, n_shards)
    b = n // n_shards
    dst = np.arange(n) // b          # destination shard of each output row
    src = perm // b                  # source shard of the row it pulls
    load = np.zeros((n_shards, n_shards), np.int64)
    np.add.at(load, (src, dst), 1)
    return load


def max_pair_load(perm, n_shards):
    """Worst bucket load — a perm is drop-free iff this <= pair_capacity."""
    return int(pair_load(perm, n_shards).max())


def assert_pair_capacity(perm, n_shards, *, slack):
    """Host-side guard: raise before launching an exchange that would drop
    rows."""
    n = np.asarray(perm).shape[0]
    cap = pair_capacity(n, n_shards, slack)
    worst = max_pair_load(perm, n_shards)
    if worst > cap:
        raise ValueError(
            f"collector exchange would drop rows: max (src, dst) load "
            f"{worst} exceeds capacity {cap} (n={n}, shards={n_shards}, "
            f"slack={slack}); raise slack or use make_balanced_perm")


def _raise_on_overflow(count):
    if int(count) > 0:
        raise RuntimeError(
            f"shuffle_shard_map dropped {int(count)} rows: per-pair bucket "
            f"capacity exceeded — raise slack or use make_balanced_perm")


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "axis", "slack", "use_kernel", "check_capacity"))
def shuffle_shard_map(x, perm, *, mesh, axis="data", slack=2.0,
                      use_kernel=False, check_capacity=False):
    """x: (N, ...) sharded over ``axis`` on dim 0; perm: (N,) replicated.

    Returns x[perm] with the same sharding, via an explicit all_to_all.

    Differentiable by construction: the registered VJP is this very
    function with the inverse permutation (Algorithm 1's de-shuffle), so
    the backward pass is one more all_to_all with the same schedule. The
    VJP is registered at this level — not inside the shard_map body —
    because per-shard (data-dependent) custom_vjp residuals do not survive
    shard_map transposition with replication checking off.

    ``use_kernel`` routes the local bucket permute through the Pallas
    ``collector_permute`` gather kernel (interpret-mode off-TPU);
    ``check_capacity`` adds an in-graph ``jax.debug.callback`` that raises
    if any (src, dst) bucket overflows instead of silently zero-filling.
    """
    impl = functools.partial(_shuffle_impl, mesh=mesh, axis=axis,
                             slack=slack, use_kernel=use_kernel,
                             check_capacity=check_capacity)

    @jax.custom_vjp
    def shuf(x, perm):
        return impl(x, perm)

    def shuf_fwd(x, perm):
        return impl(x, perm), perm

    def shuf_bwd(perm, g):
        # exact for drop-free perms; under bucket overflow the forward
        # already lost rows (see check_capacity), so exactness is moot
        return impl(g, jnp.argsort(perm)), None

    shuf.defvjp(shuf_fwd, shuf_bwd)
    return shuf(x, perm)


def _shard_map_maybe_norep(local, *, mesh, in_specs, out_specs, norep):
    shard_map = get_shard_map()
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if norep:
        # pallas_call has no replication rule; the kernel only touches
        # per-shard rows so skipping the check is sound. The flag was
        # renamed check_rep -> check_vma across jax versions.
        try:
            return shard_map(local, **kwargs, check_rep=False)
        except TypeError:
            return shard_map(local, **kwargs, check_vma=False)
    return shard_map(local, **kwargs)


def exchange_issue(x, perm, *, mesh, axis="data", slack=2.0,
                   use_kernel=False, check_capacity=False):
    """First (issue) half of the split exchange: bucket this shard's rows
    by destination shard and hand them to ``all_to_all``.

    Returns the in-flight buffer slot — a ``(rows, pos, valid)`` triple
    whose leading dims are sharded over ``axis``: per shard, ``rows`` is
    the ``(n_shards, cap, ...)`` received bucket block, ``pos`` the global
    output offset of each received row, ``valid`` its occupancy mask.
    Nothing about the slot depends on later compute, so a scheduler is
    free to overlap the collective with whatever runs between ``issue``
    and ``complete`` — the hook the double-buffered streaming collector
    pipelines client forwards into.
    """
    n = x.shape[0]
    n_shards = mesh_axis_size(mesh, axis)
    b = n // n_shards
    cap = pair_capacity(n, n_shards, slack)
    interpret = jax.default_backend() != "tpu"

    def local_permute(rows, idx):
        if use_kernel:
            from repro.kernels.collector_permute.ops import (
                collector_permute_ad)
            return collector_permute_ad(rows, idx, interpret)
        return rows[idx]

    def local(x_loc, perm):
        # which of MY rows does each shard need?
        # shard s needs my row r if perm[s*b + j] == sid*b + r for some j.
        # build send buckets: for each destination shard, up to cap rows.
        sid = jax.lax.axis_index(axis)
        inv = jnp.argsort(perm)                       # inv[g] = output pos
        my_rows_global = jnp.arange(b) + sid * b
        out_pos = inv[my_rows_global]                 # where my rows go
        dest = out_pos // b                           # destination shard
        # rank of each of my rows within its destination bucket
        order = jnp.argsort(dest)
        dsorted = dest[order]
        first = jnp.searchsorted(dsorted, dsorted, side="left")
        rank = jnp.arange(b) - first
        if check_capacity:
            jax.debug.callback(_raise_on_overflow, jnp.sum(rank >= cap))
        send = jnp.zeros((n_shards, cap) + x_loc.shape[1:], x_loc.dtype)
        send_pos = jnp.zeros((n_shards, cap), jnp.int32)
        slot_d = dsorted
        slot_r = jnp.minimum(rank, cap - 1)
        rows_sorted = local_permute(x_loc, order)
        send = send.at[slot_d, slot_r].set(rows_sorted)
        send_pos = send_pos.at[slot_d, slot_r].set(out_pos[order])
        valid = jnp.zeros((n_shards, cap), bool).at[slot_d, slot_r].set(
            rank < cap)
        # exchange buckets: the in-flight half of the pipeline
        recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
        recv_pos = jax.lax.all_to_all(send_pos, axis, 0, 0, tiled=False)
        recv_valid = jax.lax.all_to_all(valid, axis, 0, 0, tiled=False)
        return recv, recv_pos, recv_valid

    issue = _shard_map_maybe_norep(
        local, mesh=mesh, in_specs=(P(axis), P()),
        out_specs=(P(axis), P(axis), P(axis)), norep=use_kernel)
    return issue(x, perm)


def exchange_complete(slot, n, *, mesh, axis="data"):
    """Second (complete) half of the split exchange: place the received
    rows of an ``exchange_issue`` buffer slot at their local output
    offsets. ``n`` is the global row count of the shuffled array;
    ``exchange_complete(exchange_issue(x, perm, ...), x.shape[0], ...)``
    equals ``shuffle_shard_map(x, perm, ...)`` row for row."""
    recv, recv_pos, recv_valid = slot
    n_shards = mesh_axis_size(mesh, axis)
    b = n // n_shards
    cap = recv.shape[1]

    def local(recv, recv_pos, recv_valid):
        sid = jax.lax.axis_index(axis)
        flat = recv.reshape((n_shards * cap,) + recv.shape[2:])
        fpos = recv_pos.reshape(-1) - sid * b
        fval = recv_valid.reshape(-1)
        fpos = jnp.where(fval, fpos, b)               # dropped -> OOB
        out = jnp.zeros((b,) + recv.shape[2:], recv.dtype)
        out = out.at[fpos].set(flat, mode="drop")
        return out

    complete = _shard_map_maybe_norep(
        local, mesh=mesh, in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis), norep=False)
    return complete(recv, recv_pos, recv_valid)


def _shuffle_impl(x, perm, *, mesh, axis, slack, use_kernel,
                  check_capacity):
    slot = exchange_issue(x, perm, mesh=mesh, axis=axis, slack=slack,
                          use_kernel=use_kernel,
                          check_capacity=check_capacity)
    return exchange_complete(slot, x.shape[0], mesh=mesh, axis=axis)
