"""Evaluation under the paper's three scenarios and two BN policies.

  * testing IID  — mixed-class batches; the global model (post-FedAvg the
    client copies are identical except BN) is evaluated once.
  * testing non-IID — single-class batches, the realistic SFPL deployment:
    class k's batch runs through client k's model portion (with client k's
    local BN when exclude_bn was used in aggregation).
  * RMSD — BatchNorm uses aggregated running statistics at inference.
  * CMSD — BatchNorm uses the test batch's own statistics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.metrics import classification_report


def _predict_split(split, cp, cbn, sp, sbn, x, rmsd):
    # RMSD/CMSD applies to the CLIENT-side portion only (paper §VII-A);
    # the server-side model always uses its running statistics — it was
    # trained on IID-simulating shuffled pools, so they are well-calibrated.
    a, _ = split.client_fwd(cp, cbn, x, False, rmsd)
    _, (_, logits) = split.server_loss(sp, sbn, a,
                                       jnp.zeros(x.shape[0], jnp.int32),
                                       False, True)
    return jnp.argmax(logits, axis=-1)


def evaluate_split_iid(st, split, test_x, test_y, num_classes, *,
                       rmsd=True, batch=256, client_idx=0):
    """IID test batches through the shared global model (client 0's copy)."""
    cp = jax.tree_util.tree_map(lambda a: a[client_idx], st["cp"])
    cbn = jax.tree_util.tree_map(lambda a: a[client_idx], st["cbn"])
    batch = min(batch, test_x.shape[0])
    n = (test_x.shape[0] // batch) * batch
    xs = test_x[:n].reshape(-1, batch, *test_x.shape[1:])
    ys = test_y[:n].reshape(-1, batch)
    pred_fn = jax.jit(lambda x: _predict_split(split, cp, cbn, st["sp"],
                                               st["sbn"], x, rmsd))
    preds = jnp.concatenate([pred_fn(x) for x in xs])
    return classification_report(preds, ys.reshape(-1), num_classes)


def evaluate_split_noniid(st, split, test_x, test_y, num_classes, *,
                          rmsd=False, batch=100):
    """Single-class batches: class k evaluated through client k's portion."""
    preds_all, labels_all = [], []
    pred_fn = jax.jit(
        lambda cp, cbn, x: _predict_split(split, cp, cbn, st["sp"],
                                          st["sbn"], x, rmsd))
    for k in range(num_classes):
        mask = test_y == k
        xk = test_x[mask]
        nb = max(1, xk.shape[0] // batch)
        ci = k  # client k <-> class k (positive-label partitioning)
        cp = jax.tree_util.tree_map(lambda a: a[min(ci, a.shape[0] - 1)],
                                    st["cp"])
        cbn = jax.tree_util.tree_map(lambda a: a[min(ci, a.shape[0] - 1)],
                                     st["cbn"])
        for b in range(nb):
            xb = xk[b * batch:(b + 1) * batch]
            if xb.shape[0] == 0:
                continue
            preds_all.append(pred_fn(cp, cbn, xb))
            labels_all.append(jnp.full(xb.shape[0], k, jnp.int32))
    preds = jnp.concatenate(preds_all)
    labels = jnp.concatenate(labels_all)
    return classification_report(preds, labels, num_classes)


def evaluate_fl(st, split, test_x, test_y, num_classes, *, rmsd=True,
                batch=256, client_idx=0):
    p = jax.tree_util.tree_map(lambda a: a[client_idx], st["p"])
    bn = jax.tree_util.tree_map(lambda a: a[client_idx], st["bn"])
    batch = min(batch, test_x.shape[0])
    n = (test_x.shape[0] // batch) * batch
    xs = test_x[:n].reshape(-1, batch, *test_x.shape[1:])
    ys = test_y[:n].reshape(-1, batch)

    def pred(x):
        _, (_, logits) = split.full_loss(p, bn, x,
                                         jnp.zeros(x.shape[0], jnp.int32),
                                         False, rmsd)
        return jnp.argmax(logits, axis=-1)

    pred_fn = jax.jit(pred)
    preds = jnp.concatenate([pred_fn(x) for x in xs])
    return classification_report(preds, ys.reshape(-1), num_classes)


def weight_divergence(w_a, w_b):
    """Paper Eq. (11): ||w_a - w_b|| / ||w_b|| over the flattened tree."""
    fa = jnp.concatenate([jnp.ravel(x) for x in
                          jax.tree_util.tree_leaves(w_a)])
    fb = jnp.concatenate([jnp.ravel(x) for x in
                          jax.tree_util.tree_leaves(w_b)])
    return jnp.linalg.norm(fa - fb) / jnp.maximum(jnp.linalg.norm(fb), 1e-12)
