"""Round engines for the three DCML schemes: SFPL (ours/paper), SFLv2, FL.

Simulation semantics (single host, jit-compiled):
  * clients are a stacked leading axis N on the client-side param/state trees
  * SFPL: per local-batch step, all clients forward in parallel (vmap), the
    GlobalCollector pools + shuffles smashed data, ONE server-side update
    runs on the pooled shuffled stack, per-sample activation gradients are
    de-shuffled and routed back, clients update locally (vmap). At epoch end
    ClientFedServer averages client models EXCLUDING BatchNorm.
  * SFLv2: clients are visited sequentially in random order; the single
    server-side model trains on each client's (single-class) stream in turn
    — this sequential structure is the catastrophic-forgetting mechanism
    under study and must not be parallelized. Epoch end: FedAvg including BN
    (paper's RMSD setup).
  * FL: every client trains the full model locally; FedAvg everything.

The engine is generic over a ``SplitModel`` (client_fwd / server_loss /
full_loss closures) so the same machinery drives ResNets (paper) and the
cut-transformer LM variants.

The scheme step bodies live in ``repro.core.round`` — ONE placement-
agnostic implementation parameterized by collector strategy and placement
objects. ``sfpl_epoch`` / ``sflv2_epoch`` here are the single-device
entrypoints (thin wrappers pinning the historical signatures and
numerics); ``engine_dist`` wraps the same bodies for the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import round as RD
from repro.core.bn_policy import fedavg, aggregate_bn_state
from repro.core.round import make_client_update  # noqa: F401  (re-export)
from repro.models.common import IGNORE_LABEL, softmax_cross_entropy


@dataclasses.dataclass(frozen=True)
class SplitModel:
    # (cparams, cstate, x, training, rmsd) -> (smashed, new_cstate)
    client_fwd: Callable
    # (sparams, sstate, A, y, training, rmsd[, valid]) ->
    #     (loss, (new_sstate, logits)); the keyword-only ``valid`` row mask
    #     is required only when the engine runs with elastic participation
    server_loss: Callable
    # (params, state, x, y, training, rmsd) -> (loss, (new_state, logits))
    full_loss: Callable


def make_resnet_split(cfg, policy=None):
    """SplitModel closures for the paper's ResNet-8/32/56.

    ``policy`` (a ``models.common.ComputePolicy``) selects the
    mixed-precision compute path: master params stay f32 (autodiff through
    the in-loss cast delivers f32 grads), convs and the BN+ReLU epilogues
    run in ``policy.compute_dtype``, the smashed data crosses the collector
    in that dtype, and the loss reduces in f32 — via the fused Pallas
    ``softmax_xent`` when ``policy.fused()``.  ``None`` keeps the original
    f32 graph bit-for-bit."""
    from repro.models import resnet as R

    if policy is None:
        loss_fn = softmax_cross_entropy
    elif policy.fused():
        from repro.kernels.softmax_xent import ops as _xent
        def loss_fn(logits, y):
            return _xent.softmax_xent(logits, y,
                                      interpret=policy.kernel_interpret)
    else:
        loss_fn = softmax_cross_entropy

    def client_fwd(cp, cs, x, training=True, rmsd=None):
        return R.client_apply(cp, cs, x, training=training, rmsd=rmsd,
                              policy=policy)

    def server_loss(sp, ss, a, y, training=True, rmsd=None, valid=None):
        if valid is not None:
            # Elastic participation: absent clients' rows ride along for
            # static shapes but must be inert — zero their activations
            # (exact zero grads through jnp.where), drop their labels to
            # IGNORE_LABEL (the loss already means over valid rows), and
            # exclude them from every BN batch statistic.
            vb = valid.reshape((-1,) + (1,) * (a.ndim - 1))
            a = jnp.where(vb, a, jnp.zeros((), a.dtype))
            y = jnp.where(valid, y, IGNORE_LABEL)
        logits, nss = R.server_apply(sp, ss, a, cfg, training=training,
                                     rmsd=rmsd, policy=policy, valid=valid)
        return loss_fn(logits, y), (nss, logits)

    def full_loss(p, s, x, y, training=True, rmsd=None):
        logits, ns = R.apply(p, s, x, cfg, training=training, rmsd=rmsd,
                             policy=policy)
        return loss_fn(logits, y), (ns, logits)

    return SplitModel(client_fwd, server_loss, full_loss)


# --------------------------------------------------------------------------
# state containers

def init_dcml_state(key, init_fn, num_clients, opt_client, opt_server):
    """init_fn(key) -> ({"client":..., "server":...} params, state)."""
    params, state = init_fn(key)
    rep = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (num_clients,) + a.shape).copy(),
        t)
    return {
        "cp": rep(params["client"]),
        "cbn": rep(state["client"]),
        "sp": params["server"],
        "sbn": state["server"],
        "copt": rep(opt_client.init(params["client"])),
        "sopt": opt_server.init(params["server"]),
        "step": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# SFPL epoch (Algorithm 1 + 2)

def sfpl_epoch(key, st, data, split: SplitModel, opt_c, opt_s, *,
               num_clients, batch_size, bn_mode="cmsd", alpha=1.0,
               participation=None):
    """data: {"x": (N, n, ...), "y": (N, n)}. One epoch = scan over the
    n // batch_size local batches — ``round.sfpl_round`` with the dense
    single-device collector.

    ``bn_mode`` selects the paper's two SFPL aggregation variants:
      * "cmsd" — ClientFedServer EXCLUDES BatchNorm (params + stats stay
        local); inference uses current-batch statistics. Wins for non-IID
        testing (Table VIII).
      * "rmsd" — BatchNorm params and running stats ARE aggregated;
        inference uses the aggregated running statistics. Wins for IID
        testing (Tables VI, VII).

    ``participation`` (optional ``(num_clients,)`` or ``(steps,
    num_clients)`` bool) masks absent clients for the epoch or per step —
    see :func:`repro.core.round.sfpl_round`.
    """
    return RD.sfpl_round(
        key, st, data, split, opt_c, opt_s, num_clients=num_clients,
        batch_size=batch_size, bn_mode=bn_mode,
        collector=RD.SINGLE.collector(num_clients, alpha=alpha),
        participation=participation)


# --------------------------------------------------------------------------
# SFLv2 epoch (baseline under study)

def sflv2_epoch(key, st, data, split: SplitModel, opt_c, opt_s, *,
                num_clients, batch_size, aggregate_bn=True):
    return RD.sflv2_round(
        key, st, data, split, opt_c, opt_s, num_clients=num_clients,
        batch_size=batch_size, aggregate_bn=aggregate_bn,
        placement=RD.SINGLE)


# --------------------------------------------------------------------------
# FL (FedAvg) epoch

def fl_epoch(key, st, data, split: SplitModel, opt_full, *,
             num_clients, batch_size, aggregate_bn=True):
    """st here holds full-model copies per client:
    {"p": (N, ...), "bn": (N, ...), "opt": (N, ...), "step"}."""
    del key
    n_local = data["x"].shape[1]
    steps = n_local // batch_size

    def per_client(p, bn, opt, xk, yk, step0):
        def per_batch(inner, idx):
            p, bn, opt, step = inner
            xb = jax.lax.dynamic_slice_in_dim(xk, idx * batch_size,
                                              batch_size, axis=0)
            yb = jax.lax.dynamic_slice_in_dim(yk, idx * batch_size,
                                              batch_size, axis=0)

            def loss_fn(p_):
                loss, (ns, _) = split.full_loss(p_, bn, xb, yb, True, None)
                return loss, ns
            (loss, nbn), g = jax.value_and_grad(
                loss_fn, has_aux=True)(p)
            p_new, opt_new = opt_full.update(g, opt, p, step)
            return (p_new, nbn, opt_new, step + 1), loss

        (p, bn, opt, _), losses = jax.lax.scan(
            per_batch, (p, bn, opt, step0), jnp.arange(steps))
        return p, bn, opt, losses

    p, bn, opt, losses = jax.vmap(
        per_client, in_axes=(0, 0, 0, 0, 0, None))(
        st["p"], st["bn"], st["opt"], data["x"], data["y"], st["step"])
    p = fedavg(p, exclude_bn=False)
    bn = aggregate_bn_state(bn, aggregate=aggregate_bn)
    return dict(st, p=p, bn=bn, opt=opt, step=st["step"] + steps), losses


def init_fl_state(key, init_fn, num_clients, opt_full):
    params, state = init_fn(key)
    full_p = {"client": params["client"], "server": params["server"]}
    full_s = {"client": state["client"], "server": state["server"]}
    rep = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (num_clients,) + a.shape).copy(),
        t)
    return {"p": rep(full_p), "bn": rep(full_s),
            "opt": rep(opt_full.init(full_p)),
            "step": jnp.zeros((), jnp.int32)}
