"""Round engines for the three DCML schemes: SFPL (ours/paper), SFLv2, FL.

Simulation semantics (single host, jit-compiled):
  * clients are a stacked leading axis N on the client-side param/state trees
  * SFPL: per local-batch step, all clients forward in parallel (vmap), the
    GlobalCollector pools + shuffles smashed data, ONE server-side update
    runs on the pooled shuffled stack, per-sample activation gradients are
    de-shuffled and routed back, clients update locally (vmap). At epoch end
    ClientFedServer averages client models EXCLUDING BatchNorm.
  * SFLv2: clients are visited sequentially in random order; the single
    server-side model trains on each client's (single-class) stream in turn
    — this sequential structure is the catastrophic-forgetting mechanism
    under study and must not be parallelized. Epoch end: FedAvg including BN
    (paper's RMSD setup).
  * FL: every client trains the full model locally; FedAvg everything.

The engine is generic over a ``SplitModel`` (client_fwd / server_loss /
full_loss closures) so the same machinery drives ResNets (paper) and the
cut-transformer LM variants.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import collector as C
from repro.core.bn_policy import fedavg, aggregate_bn_state
from repro.models.common import softmax_cross_entropy


@dataclasses.dataclass(frozen=True)
class SplitModel:
    # (cparams, cstate, x, training, rmsd) -> (smashed, new_cstate)
    client_fwd: Callable
    # (sparams, sstate, A, y, training, rmsd) -> (loss, (new_sstate, logits))
    server_loss: Callable
    # (params, state, x, y, training, rmsd) -> (loss, (new_state, logits))
    full_loss: Callable


def make_resnet_split(cfg):
    """SplitModel closures for the paper's ResNet-8/32/56."""
    from repro.models import resnet as R

    def client_fwd(cp, cs, x, training=True, rmsd=None):
        return R.client_apply(cp, cs, x, training=training, rmsd=rmsd)

    def server_loss(sp, ss, a, y, training=True, rmsd=None):
        logits, nss = R.server_apply(sp, ss, a, cfg, training=training,
                                     rmsd=rmsd)
        return softmax_cross_entropy(logits, y), (nss, logits)

    def full_loss(p, s, x, y, training=True, rmsd=None):
        logits, ns = R.apply(p, s, x, cfg, training=training, rmsd=rmsd)
        return softmax_cross_entropy(logits, y), (ns, logits)

    return SplitModel(client_fwd, server_loss, full_loss)


# --------------------------------------------------------------------------
# state containers

def init_dcml_state(key, init_fn, num_clients, opt_client, opt_server):
    """init_fn(key) -> ({"client":..., "server":...} params, state)."""
    params, state = init_fn(key)
    rep = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (num_clients,) + a.shape).copy(),
        t)
    return {
        "cp": rep(params["client"]),
        "cbn": rep(state["client"]),
        "sp": params["server"],
        "sbn": state["server"],
        "copt": rep(opt_client.init(params["client"])),
        "sopt": opt_server.init(params["server"]),
        "step": jnp.zeros((), jnp.int32),
    }


# --------------------------------------------------------------------------
# SFPL epoch (Algorithm 1 + 2)

def make_client_update(split: SplitModel, opt_c):
    """Per-client local backprop + optimizer step given routed-back dA.

    Shared by the single-device and the mesh-sharded SFPL engines so the two
    stay numerically interchangeable by construction.
    """
    def client_upd(cp, cbn, copt, x, da, step):
        def f(cp_):
            a, ncs = split.client_fwd(cp_, cbn, x, True, None)
            return a, ncs
        _, vjp, ncs = jax.vjp(f, cp, has_aux=True)
        g_cp = vjp(da)[0]
        cp_new, copt_new = opt_c.update(g_cp, copt, cp, step)
        return cp_new, copt_new, ncs
    return client_upd

def sfpl_epoch(key, st, data, split: SplitModel, opt_c, opt_s, *,
               num_clients, batch_size, bn_mode="cmsd", alpha=1.0):
    """data: {"x": (N, n, ...), "y": (N, n)}. One epoch = scan over the
    n // batch_size local batches.

    ``bn_mode`` selects the paper's two SFPL aggregation variants:
      * "cmsd" — ClientFedServer EXCLUDES BatchNorm (params + stats stay
        local); inference uses current-batch statistics. Wins for non-IID
        testing (Table VIII).
      * "rmsd" — BatchNorm params and running stats ARE aggregated;
        inference uses the aggregated running statistics. Wins for IID
        testing (Tables VI, VII).
    """
    n_local = data["x"].shape[1]
    steps = n_local // batch_size
    coll = C.GlobalCollector(num_clients, alpha=alpha)

    def one_step(carry, idx):
        st, key = carry
        key, kperm = jax.random.split(key)
        xb = jax.lax.dynamic_slice_in_dim(data["x"], idx * batch_size,
                                          batch_size, axis=1)
        yb = jax.lax.dynamic_slice_in_dim(data["y"], idx * batch_size,
                                          batch_size, axis=1)

        # 1. client forward (parallel across clients)
        A, ncbn = jax.vmap(
            lambda cp, cs, x: split.client_fwd(cp, cs, x, True, None)
        )(st["cp"], st["cbn"], xb)

        # 2. global collector: pool + shuffle
        a_shuf, y_shuf, perm = coll.shuffle_pool(kperm, A, yb)

        # 3. one server-side update on the shuffled stack; dA per sample
        def srv_loss(sp, a):
            loss, (nss, _) = split.server_loss(sp, st["sbn"], a, y_shuf,
                                               True, None)
            return loss, nss
        (loss, nsbn), (g_sp, g_a) = jax.value_and_grad(
            srv_loss, argnums=(0, 1), has_aux=True)(st["sp"], a_shuf)
        sp_new, sopt_new = opt_s.update(g_sp, st["sopt"], st["sp"],
                                        st["step"])

        # 4. de-shuffle dA and run client backprop locally
        dA = coll.deshuffle_grads(g_a, perm)

        client_upd = make_client_update(split, opt_c)
        cp_new, copt_new, ncbn2 = jax.vmap(
            lambda cp, cbn, copt, x, da: client_upd(cp, cbn, copt, x, da,
                                                    st["step"]))(
            st["cp"], ncbn, st["copt"], xb, dA)

        st = dict(st, cp=cp_new, cbn=ncbn2, sp=sp_new, sbn=nsbn,
                  copt=copt_new, sopt=sopt_new, step=st["step"] + 1)
        return (st, key), loss

    (st, _), losses = jax.lax.scan(one_step, (st, key),
                                   jnp.arange(steps))

    # 5. ClientFedServer: FedAvg; BN treatment per bn_mode (see docstring)
    exclude = bn_mode == "cmsd"
    st = dict(st, cp=fedavg(st["cp"], exclude_bn=exclude),
              cbn=aggregate_bn_state(st["cbn"], aggregate=not exclude))
    return st, losses


# --------------------------------------------------------------------------
# SFLv2 epoch (baseline under study)

def sflv2_epoch(key, st, data, split: SplitModel, opt_c, opt_s, *,
                num_clients, batch_size, aggregate_bn=True):
    n_local = data["x"].shape[1]
    steps = n_local // batch_size
    order = jax.random.permutation(key, num_clients)

    def per_client(carry, k):
        st = carry
        cp_k = jax.tree_util.tree_map(lambda a: a[k], st["cp"])
        cbn_k = jax.tree_util.tree_map(lambda a: a[k], st["cbn"])
        copt_k = jax.tree_util.tree_map(lambda a: a[k], st["copt"])
        xk = data["x"][k]
        yk = data["y"][k]

        def per_batch(inner, idx):
            cp, cbn, copt, sp, sbn, sopt, step = inner
            xb = jax.lax.dynamic_slice_in_dim(xk, idx * batch_size,
                                              batch_size, axis=0)
            yb = jax.lax.dynamic_slice_in_dim(yk, idx * batch_size,
                                              batch_size, axis=0)

            def f(cp_):
                a, ncs = split.client_fwd(cp_, cbn, xb, True, None)
                return a, ncs
            A, vjp, ncbn = jax.vjp(f, cp, has_aux=True)

            def srv_loss(sp_, a):
                loss, (nss, _) = split.server_loss(sp_, sbn, a, yb, True,
                                                   None)
                return loss, nss
            (loss, nsbn), (g_sp, g_a) = jax.value_and_grad(
                srv_loss, argnums=(0, 1), has_aux=True)(sp, A)
            sp_new, sopt_new = opt_s.update(g_sp, sopt, sp, step)
            g_cp = vjp(g_a)[0]
            cp_new, copt_new = opt_c.update(g_cp, copt, cp, step)
            return (cp_new, ncbn, copt_new, sp_new, nsbn, sopt_new,
                    step + 1), loss

        inner0 = (cp_k, cbn_k, copt_k, st["sp"], st["sbn"], st["sopt"],
                  st["step"])
        inner, losses = jax.lax.scan(per_batch, inner0, jnp.arange(steps))
        cp_k, cbn_k, copt_k, sp, sbn, sopt, step = inner
        put = lambda t, v: jax.tree_util.tree_map(
            lambda a, b: a.at[k].set(b), t, v)
        st = dict(st, cp=put(st["cp"], cp_k), cbn=put(st["cbn"], cbn_k),
                  copt=put(st["copt"], copt_k), sp=sp, sbn=sbn, sopt=sopt,
                  step=step)
        return st, losses

    st, losses = jax.lax.scan(per_client, st, order)
    st = dict(st, cp=fedavg(st["cp"], exclude_bn=False),
              cbn=aggregate_bn_state(st["cbn"], aggregate=aggregate_bn))
    return st, losses


# --------------------------------------------------------------------------
# FL (FedAvg) epoch

def fl_epoch(key, st, data, split: SplitModel, opt_full, *,
             num_clients, batch_size, aggregate_bn=True):
    """st here holds full-model copies per client:
    {"p": (N, ...), "bn": (N, ...), "opt": (N, ...), "step"}."""
    del key
    n_local = data["x"].shape[1]
    steps = n_local // batch_size

    def per_client(p, bn, opt, xk, yk, step0):
        def per_batch(inner, idx):
            p, bn, opt, step = inner
            xb = jax.lax.dynamic_slice_in_dim(xk, idx * batch_size,
                                              batch_size, axis=0)
            yb = jax.lax.dynamic_slice_in_dim(yk, idx * batch_size,
                                              batch_size, axis=0)

            def loss_fn(p_):
                loss, (ns, _) = split.full_loss(p_, bn, xb, yb, True, None)
                return loss, ns
            (loss, nbn), g = jax.value_and_grad(
                loss_fn, has_aux=True)(p)
            p_new, opt_new = opt_full.update(g, opt, p, step)
            return (p_new, nbn, opt_new, step + 1), loss

        (p, bn, opt, _), losses = jax.lax.scan(
            per_batch, (p, bn, opt, step0), jnp.arange(steps))
        return p, bn, opt, losses

    p, bn, opt, losses = jax.vmap(
        per_client, in_axes=(0, 0, 0, 0, 0, None))(
        st["p"], st["bn"], st["opt"], data["x"], data["y"], st["step"])
    p = fedavg(p, exclude_bn=False)
    bn = aggregate_bn_state(bn, aggregate=aggregate_bn)
    return dict(st, p=p, bn=bn, opt=opt, step=st["step"] + steps), losses


def init_fl_state(key, init_fn, num_clients, opt_full):
    params, state = init_fn(key)
    full_p = {"client": params["client"], "server": params["server"]}
    full_s = {"client": state["client"], "server": state["server"]}
    rep = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (num_clients,) + a.shape).copy(),
        t)
    return {"p": rep(full_p), "bn": rep(full_s),
            "opt": rep(opt_full.init(full_p)),
            "step": jnp.zeros((), jnp.int32)}
