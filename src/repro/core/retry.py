"""Jittered exponential-backoff retry — the bring-up side of fault tolerance.

Multi-host bring-up is the one place the stack talks to an unreliable
outside world: ``jax.distributed.initialize`` races the coordinator's
listen socket, and on a flaky fabric the first join attempt of a late
process routinely lands on ECONNREFUSED.  ``retry_call`` wraps any such
call with capped exponential backoff plus deterministic jitter (seeded,
so N processes retrying the same coordinator decorrelate without a shared
clock), and raises a :class:`RetryError` naming the call, the attempt
budget, and the last underlying error once the budget is exhausted.

The clock is injectable (``sleep=``) so tests drive the schedule without
wall time; the jitter stream is seeded (``seed=``) so the schedule is
reproducible — both matter for the deterministic fault harness in
``core/faults.py``.
"""
from __future__ import annotations

import logging
import time

import numpy as np

_log = logging.getLogger(__name__)


class RetryError(RuntimeError):
    """Terminal failure after the retry budget is exhausted.

    ``last`` holds the final underlying exception (also chained via
    ``__cause__``), ``attempts`` the budget that was spent.
    """

    def __init__(self, message, *, attempts, last):
        super().__init__(message)
        self.attempts = attempts
        self.last = last


def backoff_schedule(attempts, *, base_delay=0.5, max_delay=30.0,
                     jitter=0.5, seed=0):
    """The exact delays ``retry_call`` would sleep between attempts.

    Deterministic in ``seed``; ``attempts - 1`` entries (no sleep after the
    final failure).  Delay i is ``min(max_delay, base_delay * 2**i)``
    stretched by a uniform factor in ``[1, 1 + jitter]``.

    >>> [round(d, 3) for d in backoff_schedule(3, base_delay=1.0, jitter=0.0)]
    [1.0, 2.0]
    """
    rng = np.random.default_rng(seed)
    out = []
    for i in range(max(0, attempts - 1)):
        delay = min(float(max_delay), float(base_delay) * (2.0 ** i))
        out.append(delay * (1.0 + float(jitter) * float(rng.random())))
    return out


def retry_call(fn, *, attempts=5, base_delay=0.5, max_delay=30.0,
               jitter=0.5, seed=0, retry_on=(Exception,), sleep=time.sleep,
               describe=None):
    """Call ``fn()`` with up to ``attempts`` tries and jittered backoff.

    Only exceptions matching ``retry_on`` are retried; anything else
    propagates immediately (a typo should not burn the whole budget).
    After the last failed attempt a :class:`RetryError` is raised from the
    final underlying exception, so the terminal traceback shows both the
    budget and the root cause.
    """
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    name = describe or getattr(fn, "__name__", "call")
    delays = backoff_schedule(attempts, base_delay=base_delay,
                              max_delay=max_delay, jitter=jitter, seed=seed)
    last = None
    for i in range(attempts):
        try:
            return fn()
        except retry_on as e:  # noqa: PERF203 — the loop IS the feature
            last = e
            if i == attempts - 1:
                break
            _log.warning("%s failed (attempt %d/%d): %r — retrying in %.2fs",
                         name, i + 1, attempts, e, delays[i])
            sleep(delays[i])
    raise RetryError(
        f"{name} failed after {attempts} attempt(s); backoff budget "
        f"exhausted. Last error: {last!r}", attempts=attempts,
        last=last) from last
