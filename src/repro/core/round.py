"""Placement-agnostic DCML round engine.

The paper's schemes previously lived as two parallel engine stacks —
``engine.py`` (single-device) and ``engine_dist.py`` (mesh-sharded SFPL) —
duplicating the per-step structure and diverging on collector semantics.
This module is the single implementation both delegate to:

  * a ``Placement`` says WHERE state and batches live: ``SingleDevice``
    or a ``DataMesh`` over a ``("data",)`` axis;
  * a ``CollectorStrategy`` says HOW Algorithm 1's collect-shuffle-scatter
    runs: ``DenseTake`` (one-device ``jnp.take``), ``MeshAllToAll``
    (explicit ``all_to_all`` with balanced, grouped-balanced, or uniform
    permutations and auto-sized exchange slack), or ``StreamingAllToAll``
    (the same exchange double-buffered per flush group: issue/complete
    halves with the next group's client forward between them, drained
    after the last group — the paper's threshold-queue collector as a
    two-slot software pipeline).

The mesh strategies are driven by precomputed **route plans**
(``collector_dist.RoutePlan``): because the permutation is replicated,
``prepare`` builds the routing metadata — O(n) scatter inverse, per-row
destination shard, bucket slot, receive placement — ONCE per step and
``sfpl_round`` threads the prepared permutation through the scan body, so
the label permute, the activation permute, the custom-VJP backward
exchange, and the streaming ``route_back`` all share it. Balanced and
grouped-balanced modes run the dense fast path (exact per-pair capacity,
no overflow accounting, zero slack padding for one global flush).

Gradient DE-shuffling is never hand-derived: ``DenseTake`` and
``MeshAllToAll`` expose a differentiable ``permute`` and the server loss
is taken as a function of the PRE-shuffle pooled stack, so autodiff emits
the inverse route (dense scatter or the plan exchange with the backward
plan) and hands each client exactly its own activation gradients.
``StreamingAllToAll`` assembles the shuffled pool outside the loss (the
forwards must interleave with the exchanges), so it routes explicitly —
``route_back`` is the identical exchange under the backward plans.

Shape contract shared by every strategy: the pool is client-major,
``(num_clients * batch_size, ...)`` with row ``c * batch_size + j`` being
sample ``j`` of client ``c``; ``make_perm`` returns a replicated ``(n,)``
permutation that never crosses flush-group boundaries —

>>> from repro.core.collector import flush_group_sizes
>>> flush_group_sizes(8, 0.25)     # alpha=0.25: four 2-client flushes
[2, 2, 2, 2]

Flush groups (the paper's ``alpha`` accumulation threshold) work on every
placement: ``DenseTake`` shuffles within contiguous client groups, and
``MeshAllToAll`` builds per-flush-group balanced permutations aligned to
shard boundaries (``collector_dist.make_grouped_balanced_perm``) with
slack sized to the worst group's bucket load.

SFLv2's deliberate sequential client visitation (the catastrophic-
forgetting mechanism under study) is preserved on every placement:
``sflv2_round`` shards the per-client batch axis — and with it the
server-side update stream, the scaling bottleneck in SplitFed's framing —
never the visitation loop.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collector as C
from repro.core.bn_policy import fedavg, aggregate_bn_state
from repro.core.collector_dist import (
    _resolve_wire, axis_tuple, balanced_stream_slack, build_route_plans,
    build_submesh_route_plans, exact_pair_cap, make_grouped_balanced_perm,
    mesh_axis_size, pair_capacity, plan_exchange, plan_exchange_complete,
    plan_exchange_issue, plan_payload_bytes, plan_shuffle,
    submesh_slice_size, uniform_auto_slack)
from repro.kernels._compat import auto_use_kernel

logger = logging.getLogger(__name__)


class PreparedPerm(NamedTuple):
    """A step's permutation with its precomputed routing: ``plans`` is the
    strategy-specific payload — ``None`` for ``DenseTake``, one
    ``(forward, backward)`` ``RoutePlan`` pair for ``MeshAllToAll``, and a
    per-flush-group tuple of pairs for ``StreamingAllToAll``. Built once
    per scan step (``collector.prepare``) and shared by every use of the
    permutation in that step: the label permute, the activation permute,
    the custom-VJP backward exchange, and the streaming route_back."""
    perm: jax.Array
    plans: object


def resolve_use_kernel(flag):
    """``None`` means auto: the fused Pallas bucket kernels are on where
    they win — compiled TPU lowering — and off elsewhere (off-TPU they
    only run in interpret mode, which the CPU-harness benchmarks show
    losing to the jnp gathers)."""
    return auto_use_kernel(flag)


# --------------------------------------------------------------------------
# placements

@dataclasses.dataclass(frozen=True)
class SingleDevice:
    """Everything on one device — the simulation default."""

    def place_state(self, st):
        return st

    def place_data(self, data):
        return data

    def constrain_batch(self, tree):
        return tree

    def collector(self, num_clients, *, alpha=1.0, use_kernel=False, **_):
        return DenseTake(num_clients=num_clients, alpha=alpha,
                         use_kernel=use_kernel)


SINGLE = SingleDevice()


def _global_put(a, sharding):
    """Place a host array under ``sharding`` — ``jax.device_put`` when this
    process addresses every device of the mesh, else assembled from
    per-device host slices (each process of a multi-host mesh holds the
    full replicated host value, so any index of it is addressable)."""
    if sharding.is_fully_addressable:
        return jax.device_put(a, sharding)
    return jax.make_array_from_callback(
        np.shape(a), sharding, lambda idx: np.asarray(a)[idx])


@dataclasses.dataclass(frozen=True)
class DataMesh:
    """A device mesh: client-stacked state and the pooled smashed batch are
    sharded over ``axis``; server state stays replicated. ``axis`` is a
    bare axis name on the 1-D ``("data",)`` mesh, or the pod-major name
    tuple ``("pod", "data")`` of the 2-D multi-host mesh — dim 0 then
    shards jointly over both axes, pod-major, so the flattened device
    index is the collector shard index."""
    mesh: object
    axis: object = "data"

    @property
    def n_shards(self):
        return mesh_axis_size(self.mesh, self.axis)

    def place_state(self, st):
        """Place an ``init_dcml_state`` tree: client-stacked leaves sharded
        on their leading (client) axis, server leaves replicated."""
        shard = NamedSharding(self.mesh, P(self.axis))
        repl = NamedSharding(self.mesh, P())
        put = lambda t, s: jax.tree_util.tree_map(
            lambda a: _global_put(a, s), t)
        return dict(
            st,
            cp=put(st["cp"], shard), cbn=put(st["cbn"], shard),
            copt=put(st["copt"], shard),
            sp=put(st["sp"], repl), sbn=put(st["sbn"], repl),
            sopt=put(st["sopt"], repl),
            step=_global_put(st["step"], repl))

    def place_data(self, data):
        """Shard the per-client dataset {"x": (N, n, ...), "y": (N, n)} over
        the client axis."""
        shard = NamedSharding(self.mesh, P(self.axis))
        return jax.tree_util.tree_map(
            lambda a: _global_put(a, shard), data)

    def constrain_batch(self, tree):
        """Shard the leading (batch) axis of every leaf — the SFLv2 server
        stream runs data-parallel over the mesh without touching the
        sequential visitation order."""
        def c(a):
            spec = P(self.axis) if a.ndim >= 1 else P()
            return jax.lax.with_sharding_constraint(
                a, NamedSharding(self.mesh, spec))
        return jax.tree_util.tree_map(c, tree)

    def collector(self, num_clients, *, alpha=1.0, mode="balanced",
                  slack=None, use_kernel=None, check_capacity=False,
                  pipeline="sync", stream_slack=None, submesh=None,
                  wire_dtype=None, wire_dtype_bwd=None):
        if pipeline not in ("sync", "double_buffered"):
            raise ValueError(f"unknown collector pipeline {pipeline!r}: "
                             f"expected 'sync' or 'double_buffered'")
        common = dict(mesh=self.mesh, num_clients=num_clients,
                      axis=self.axis, mode=mode, alpha=alpha,
                      slack=slack, use_kernel=use_kernel,
                      check_capacity=check_capacity,
                      wire_dtype=wire_dtype, wire_dtype_bwd=wire_dtype_bwd)
        if pipeline == "double_buffered":
            return StreamingAllToAll(stream_slack=stream_slack,
                                     submesh=submesh, **common)
        if submesh:
            raise ValueError(
                "collector_submesh applies to the double_buffered "
                "pipeline (the sync exchange is already dense for "
                "balanced permutations); drop the flag or use "
                "pipeline='double_buffered'")
        return MeshAllToAll(**common)


# --------------------------------------------------------------------------
# collector strategies

@dataclasses.dataclass(frozen=True)
class DenseTake:
    """Algorithm 1's collector as a dense gather on one device."""
    num_clients: int
    alpha: float = 1.0
    use_kernel: bool = False

    def make_perm(self, key, n):
        return C.make_flush_perm(key, n, self.num_clients, self.alpha)

    def prepare(self, perm, n):
        """A dense gather needs no routing metadata beyond the perm."""
        return PreparedPerm(perm, None)

    def permute(self, x, prep):
        perm = prep.perm if isinstance(prep, PreparedPerm) else prep
        if self.use_kernel and jnp.issubdtype(x.dtype, jnp.floating):
            return C.shuffle(x, perm, use_kernel=True)
        return jnp.take(x, perm, axis=0)

    def exchange_bytes(self, prep, row_elems, dtype):
        """Wire bytes of one pool shuffle: a single-device gather never
        crosses a device boundary."""
        return 0


@dataclasses.dataclass(frozen=True)
class MeshAllToAll:
    """Algorithm 1's collector as one explicit ``all_to_all`` per step,
    driven by a per-step route plan (``prepare``).

    ``mode``:
      * "balanced" — balanced block permutations (grouped when alpha < 1)
        whose per-pair bucket loads are deterministic, so the plan runs
        the DENSE fast path: exact capacity (``exact_pair_cap``), no
        overflow accounting, zero slack padding for one global flush;
      * "uniform"  — the paper-faithful uniform shuffle (identical perm
        distribution to ``DenseTake``), slack-buffered with the capacity
        auto-sized from probe ``max_pair_load`` draws and the in-graph
        capacity check forced on so an unlucky permutation raises instead
        of dropping rows.
    ``slack=None`` auto-sizes per mode; pass a float to override (which
    forces the slack-buffered plan shape even in balanced mode).
    ``use_kernel=None`` (auto) fuses the local bucket gathers into the
    Pallas kernels on TPU and keeps the jnp gathers elsewhere.
    ``wire_dtype`` narrows the smashed rows' on-wire dtype
    (``core.wire.WIRE_DTYPE_NAMES``) — quantized wires ship per-row f32
    scales as packed extra payload columns of the same collective;
    ``wire_dtype_bwd`` independently opts the routed-back gradient rows
    into a narrow wire (default exact f32/compute-dtype backward).
    """
    mesh: object
    num_clients: int
    axis: object = "data"
    mode: str = "balanced"
    alpha: float = 1.0
    slack: Optional[float] = None
    use_kernel: Optional[bool] = None
    check_capacity: bool = False
    wire_dtype: Optional[str] = None
    wire_dtype_bwd: Optional[str] = None

    pipelined = False

    def group_rows(self, n):
        per_client = n // self.num_clients
        return [c * per_client
                for c in C.flush_group_sizes(self.num_clients, self.alpha)]

    def plan_spec(self, n):
        """(cap, may_drop) of the step exchange's route plan. Balanced
        modes get the exact capacity; they only skip overflow accounting
        (the dense path) when the caller did NOT ask for the in-graph
        capacity check — ``check_capacity=True`` must keep its raise-on-
        overflow contract even against a mis-declared permutation."""
        n_shards = mesh_axis_size(self.mesh, self.axis)
        if self.slack is not None:
            return pair_capacity(n, n_shards, self.slack), True
        rows = self.group_rows(n)
        if self.mode == "uniform":
            slack = uniform_auto_slack(
                n, n_shards, rows if len(rows) > 1 else None)
            return pair_capacity(n, n_shards, slack), True
        return exact_pair_cap(n, n_shards, rows), self.check_capacity

    def make_perm(self, key, n):
        if self.mode == "uniform":
            return C.make_flush_perm(key, n, self.num_clients, self.alpha)
        n_shards = mesh_axis_size(self.mesh, self.axis)
        return make_grouped_balanced_perm(key, n, n_shards,
                                          self.group_rows(n))

    def prepare(self, perm, n):
        """Build the (forward, backward) route plans once; every permute
        and the VJP exchange of the step share them."""
        cap, may_drop = self.plan_spec(n)
        n_shards = mesh_axis_size(self.mesh, self.axis)
        return PreparedPerm(perm, build_route_plans(
            perm, n_shards, cap=cap, may_drop=may_drop))

    def _check(self):
        return self.check_capacity or (self.mode == "uniform"
                                       and self.slack is None)

    def _use_k(self, dtype):
        return (resolve_use_kernel(self.use_kernel)
                and jnp.issubdtype(dtype, jnp.floating))

    def _wire(self, dtype):
        """Effective wire of a ``dtype`` payload: ``None`` when rows ship
        as computed (no-op wires, non-float payloads like the label
        permute), else the resolved wire name."""
        return _resolve_wire(jnp.dtype(dtype), self.wire_dtype)

    def permute(self, x, prep):
        if not isinstance(prep, PreparedPerm):
            prep = self.prepare(prep, x.shape[0])
        return plan_shuffle(
            x, prep.plans, mesh=self.mesh, axis=self.axis,
            use_kernel=self._use_k(x.dtype), check_capacity=self._check(),
            wire_dtype=self.wire_dtype, wire_dtype_bwd=self.wire_dtype_bwd)

    def exchange_bytes(self, prep, row_elems, dtype):
        """Wire bytes of one forward pool exchange (the activation
        ``all_to_all``) for ``row_elems``-element rows in ``dtype`` —
        ``collector_dist.plan_payload_bytes`` of the step's forward plan,
        in the strategy's EFFECTIVE wire dtype (scale sidecar included
        for quantized wires). Plan shapes are dtype-independent, so bf16
        smashed data is exactly half the f32 payload at a matched
        config, and an int8 wire is a quarter plus 4 scale bytes/row."""
        return plan_payload_bytes(prep.plans[0], row_elems,
                                  jnp.dtype(dtype).itemsize,
                                  wire_dtype=self._wire(dtype))


@dataclasses.dataclass(frozen=True)
class StreamingAllToAll(MeshAllToAll):
    """The paper's threshold-queue collector as a two-slot software
    pipeline: each flush group is exchanged with its OWN all_to_all, split
    into issue/complete halves, so the exchange of group ``k`` is in
    flight while the client forward of group ``k+1`` computes.

    Semantics are identical to ``MeshAllToAll`` with the same ``mode`` /
    ``alpha`` — the per-group exchange moves exactly the rows the one big
    grouped exchange would (the grouped permutation never crosses flush
    groups), so the shuffled pool, and with it the loss trajectory, is
    bit-comparable to the synchronous path. What changes is the dataflow:
    ``sfpl_round`` produces the pool group by group and ``streamed_shuffle``
    keeps one filled buffer slot in flight, draining the last one after
    the loop.

    Because the shuffled pool is assembled OUTSIDE the server loss (the
    forwards must interleave with the exchanges), gradient routing is
    explicit here: ``route_back`` runs the same per-group exchange with
    the inverse permutation — exactly what autodiff emits for the
    synchronous strategy's in-loss ``permute``.

    ``submesh`` selects the group-structured SUB-MESH exchange: when the
    grouped-balanced layout qualifies (``collector_dist.
    submesh_slice_size`` — every flush group covers the same number ``S``
    of whole shard slabs and ``b % S == 0``), each group's collective is
    confined to its owning ``S``-shard slice via ``axis_index_groups``
    and the per-group plan is DENSE: exact capacity ``b/S`` per in-slice
    pair, no overflow counter, no pad row, zero slack — each group's send
    buffer is exactly the ``b``-row slab per shard instead of the
    whole-mesh fallback's ``n_g + n_shards`` rows. ``None`` (default)
    auto-enables
    it exactly when the layout qualifies; ``True`` raises on layouts that
    don't; ``False`` forces the whole-mesh fallback. The pool-width
    dataflow also changes: the full client forward runs once (each
    shard's clients ARE its groups' rows — the forward is already
    slice-local), and the per-group collectives on disjoint slices
    pipeline against each other and the completes.

    ``stream_slack`` sizes the whole-mesh fallback's per-group exchange
    buffers (setting it opts OUT of sub-mesh routing — the fallback
    re-shards each group over the whole mesh, where group permutations
    have non-deterministic loads under the ``b_g = n_g / n_shards``-row
    fine slabs). The default ``None`` auto-sizes by PROBING each distinct
    group size's actual permutation family: uniform groups through
    ``uniform_auto_slack``, balanced groups through
    ``balanced_stream_slack`` (sample balanced block exchanges measured
    against the fine slabs, clamped at the capacity-safe ``n_shards``
    ceiling they used to default to). Both probes are memoized per
    ``(n_g, n_shards)``-shaped key and both force the in-graph capacity
    check on, exactly like the sync uniform path, so an unlucky draw
    raises instead of dropping rows.

    Layout contract: every flush group's row count must divide by the
    shard count (each group is row-sharded over the whole mesh for its
    exchange) OR the layout must qualify for sub-mesh routing;
    ``engine_dist.check_sfpl_layout(...,
    collector_pipeline="double_buffered")`` validates this eagerly.
    """
    stream_slack: Optional[float] = None
    submesh: Optional[bool] = None

    pipelined = True

    def group_bounds(self, n):
        """Static (start, stop) row ranges of the flush groups in the
        client-major pool."""
        bounds, start = [], 0
        for size in self.group_rows(n):
            bounds.append((start, start + size))
            start += size
        return bounds

    def client_groups(self):
        """Static (first, last+1) client ranges of the flush groups."""
        out, c0 = [], 0
        for c in C.flush_group_sizes(self.num_clients, self.alpha):
            out.append((c0, c0 + c))
            c0 += c
        return out

    def submesh_slices(self, n):
        """Shards per owning slice when sub-mesh routing is active for a
        ``n``-row pool, else ``None`` (auto-resolution of the ``submesh``
        knob). ``submesh=True`` raises on non-qualifying layouts with the
        disqualifying condition named. On a 2-D ``("pod", "data")`` mesh a
        qualifying slice must additionally stay POD-LOCAL (whole mesh, or
        dividing the per-pod shard count): a slice straddling pods has no
        grouped-collective expression, so those layouts fall back to the
        probed-slack whole-mesh exchange — logged, never silently
        dropped."""
        if self.submesh is False:
            return None
        reason, slices = None, None
        if self.mode != "balanced":
            reason = ("sub-mesh routing needs the deterministic per-pair "
                      "loads of collector_mode='balanced'; uniform "
                      "permutations fall back to the slack-buffered "
                      "whole-mesh exchange")
        elif self.slack is not None or self.stream_slack is not None:
            reason = ("an explicit slack/stream_slack override forces the "
                      "slack-buffered whole-mesh plan shape")
        else:
            n_shards = mesh_axis_size(self.mesh, self.axis)
            slices = submesh_slice_size(n, n_shards, self.group_rows(n))
            if slices is None:
                reason = ("every flush group must cover the same number "
                          "of whole shard slabs, with the slab divisible "
                          "by that span (collector_dist."
                          "submesh_slice_size)")
            else:
                names = axis_tuple(self.axis)
                if len(names) > 1 and slices != n_shards:
                    inner = mesh_axis_size(self.mesh, names[-1])
                    if inner % slices:
                        reason = (
                            f"a {slices}-shard slice straddles the pod "
                            f"boundary (per-pod axis {names[-1]!r} holds "
                            f"{inner} shards) — cross-pod flush groups "
                            f"take the probed-slack whole-mesh exchange")
                        slices = None
                        if not self.submesh:
                            logger.warning(
                                "sub-mesh routing disabled: %s", reason)
        if slices is None and self.submesh:
            raise ValueError(
                f"collector_submesh=True but the layout does not qualify "
                f"for the sub-mesh streaming exchange: {reason} "
                f"(num_clients={self.num_clients}, alpha={self.alpha}, "
                f"n={n}, shards="
                f"{mesh_axis_size(self.mesh, self.axis)})")
        return slices

    def _check(self):
        # BOTH whole-mesh fallback auto slacks are PROBED per group size
        # now (empirical, not worst-case) — uniform via
        # ``uniform_auto_slack``, balanced via ``balanced_stream_slack`` —
        # so the in-graph capacity check is forced on whenever they may be
        # in play. Dense sub-mesh plans carry no overflow counter, so the
        # flag is inert on that path.
        return self.check_capacity or (self.slack is None
                                       and self.stream_slack is None)

    def _sub_slack(self, n_g, span=1):
        """Whole-mesh fallback slack for one ``n_g``-row flush group.
        ``span`` is the number of original shard slabs the group covers
        (the block count of its grouped-balanced sub-permutation)."""
        if self.stream_slack is not None:
            return self.stream_slack
        n_shards = mesh_axis_size(self.mesh, self.axis)
        if self.mode == "uniform":
            # probed at the group's own row count — the memo key
            # (n_g, n_shards) is shared by every same-sized group and
            # every re-trace, so the probe permutations run once
            return uniform_auto_slack(n_g, n_shards)
        # balanced fallback: probe the group's actual permutation family
        # (balanced over ``span`` blocks, uniform in-slab at span <= 1)
        # against the fine b_g-row slabs, clamped at the capacity-safe
        # slack = n_shards ceiling (cap = b_g + 1 per pair) it replaces —
        # memoized like the uniform probe, checked in-graph like it too.
        # The sub-mesh path replaces this entirely: its per-group plans
        # are dense (cap exactly b/S, no slack) because the group never
        # leaves its own slice.
        return balanced_stream_slack(n_g, n_shards, span)

    def _sub_perm(self, perm, bounds):
        r0, r1 = bounds
        return jax.lax.slice_in_dim(perm, r0, r1, axis=0) - r0

    def prepare(self, perm, n):
        """Per-flush-group (forward, backward) route plans, built once per
        step and shared by the issue/complete exchanges AND ``route_back``
        — the streamed counterpart of ``MeshAllToAll.prepare``. With
        sub-mesh routing active, every pair is DENSE
        (``build_submesh_route_plans``); otherwise each group gets
        slack-buffered whole-mesh plans at its own ``_sub_slack``."""
        n_shards = mesh_axis_size(self.mesh, self.axis)
        slices = self.submesh_slices(n)
        b = n // n_shards
        plans = []
        for g, bounds in enumerate(self.group_bounds(n)):
            sub = self._sub_perm(perm, bounds)
            if slices is not None:
                plans.append(build_submesh_route_plans(
                    sub, g, n_shards, slices))
            else:
                n_g = bounds[1] - bounds[0]
                # slab span of the group's sub-permutation: >1 only for
                # groups that got a balanced block exchange
                # (make_grouped_balanced_perm's aligned, multi-slab case)
                span = n_g // b if n_g % b == 0 else 1
                cap = pair_capacity(n_g, n_shards,
                                    self._sub_slack(n_g, span))
                plans.append(build_route_plans(sub, n_shards, cap=cap,
                                               may_drop=True))
        return PreparedPerm(perm, tuple(plans))

    @staticmethod
    def _plans_are_submesh(prep):
        return prep.plans[0][0].slice_size is not None

    def permute(self, x, prep, skip=None):
        """Blocking whole-pool shuffle under the per-group plans (used for
        the label pool, which never interleaves with client compute):
        each sealed flush group is one plan exchange. Sub-mesh plans take
        the whole pool (each exchange is confined to its slice by
        ``axis_index_groups``) and the group outputs are mask-combined;
        fallback plans take the group's rows and the outputs concatenate.
        ``skip`` (per-group bools — elastic participation) passes a fully
        dropped group's rows through unexchanged: every row is masked
        downstream, so the collective would only move dead payload."""
        n = x.shape[0]
        if not isinstance(prep, PreparedPerm):
            prep = self.prepare(prep, n)
        parts = []
        for g, (r0, r1) in enumerate(self.group_bounds(n)):
            rows = (x if self._plans_are_submesh(prep)
                    else jax.lax.slice_in_dim(x, r0, r1, axis=0))
            if skip and skip[g]:
                parts.append(rows)
                continue
            parts.append(plan_shuffle(
                rows, prep.plans[g],
                mesh=self.mesh, axis=self.axis,
                use_kernel=self._use_k(x.dtype),
                check_capacity=self._check(),
                wire_dtype=self.wire_dtype,
                wire_dtype_bwd=self.wire_dtype_bwd))
        return self.assemble(parts, prep, n)

    def assemble(self, parts, prep, n):
        """Combine per-group exchange outputs into the shuffled pool."""
        if self._plans_are_submesh(prep):
            return _combine_slices(parts, self.group_bounds(n))
        return _concat_parts(parts)

    def issue(self, rows, prep, g):
        """Launch flush group ``g``'s exchange; returns the in-flight
        buffer slot (``collector_dist.plan_exchange_issue``). ``rows`` is
        the group's pooled rows on the fallback path, the WHOLE pool on
        the sub-mesh path (where the plan's ``axis_index_groups`` confine
        the collective to group ``g``'s slice)."""
        return plan_exchange_issue(
            rows, prep.plans[g][0], mesh=self.mesh, axis=self.axis,
            use_kernel=self._use_k(rows.dtype),
            check_capacity=self._check(), wire_dtype=self.wire_dtype)

    def complete(self, slot):
        """Land an in-flight buffer slot: the group's shuffled rows. The
        kernel decision reads the slot's wire context, not the received
        buffer — under a quantized wire ``recv`` is the packed int8/fp8
        block, but the gather lands compute-dtype rows."""
        recv, _, ctx = slot
        dtype = recv.dtype if ctx is None else ctx[1]
        return plan_exchange_complete(
            slot, mesh=self.mesh, axis=self.axis,
            use_kernel=self._use_k(dtype))

    def exchange_bytes(self, prep, row_elems, dtype, skip=None):
        """Wire bytes of one forward pool exchange: the sum of the
        per-flush-group collectives' ``plan_payload_bytes`` in the
        strategy's effective wire dtype. ``skip`` (per-group bools —
        elastic participation) excludes groups whose exchange is
        statically skipped: a fully dropped flush group's rows pass
        through unexchanged, so no collective runs and no bytes cross
        the wire for it."""
        itemsize = jnp.dtype(dtype).itemsize
        wire = self._wire(dtype)
        return sum(plan_payload_bytes(plans[0], row_elems, itemsize,
                                      wire_dtype=wire)
                   for g, plans in enumerate(prep.plans)
                   if not (skip and skip[g]))

    def route_back(self, g_shuf, prep, n, skip=None):
        """Algorithm 1's de-shuffle, explicit: the per-group exchange with
        the BACKWARD plan of the shared ``prepare`` hands each client its
        own activation gradients — move-for-move what autodiff emits for
        the synchronous path, so trajectories stay bit-comparable.
        ``skip`` mirrors the forward skip of a fully dropped flush group
        (its gradient rows are exact zeros — nothing to route)."""
        if not isinstance(prep, PreparedPerm):
            prep = self.prepare(prep, n)
        submesh = self._plans_are_submesh(prep)
        parts = []
        for g, (r0, r1) in enumerate(self.group_bounds(n)):
            rows = (g_shuf if submesh
                    else jax.lax.slice_in_dim(g_shuf, r0, r1, axis=0))
            if skip and skip[g]:
                parts.append(rows)
                continue
            parts.append(plan_exchange(
                rows, prep.plans[g][1], mesh=self.mesh, axis=self.axis,
                use_kernel=self._use_k(g_shuf.dtype),
                wire_dtype=self.wire_dtype_bwd))
        return self.assemble(parts, prep, n)


def _concat_parts(parts):
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def _combine_slices(parts, bounds):
    """Assemble pool-width sub-mesh exchange outputs: part ``g`` is valid
    only at rows ``bounds[g]`` (its owning slice's slabs — the other
    shards exchanged garbage within their own slices). A row-index masked
    select keeps every array in the pool's home sharding — concatenating
    slices of a sharded pool would force a re-layout — and is exact under
    autodiff: the cotangent reaching part ``g`` is zero outside its slice,
    so each backward exchange contributes only its own slice's gradients."""
    if len(parts) == 1:
        return parts[0]
    out = parts[0]
    rows = jnp.arange(out.shape[0])
    for (r0, r1), part in zip(bounds[1:], parts[1:]):
        mask = ((rows >= r0) & (rows < r1)).reshape(
            (-1,) + (1,) * (part.ndim - 1))
        out = jnp.where(mask, part, out)
    return out


def streamed_shuffle(collector, prep, n, produce_group, skip=None):
    """Two-slot software pipeline over flush groups.

    ``prep`` is the step's ``collector.prepare(perm, n)`` (a bare
    permutation is accepted and prepared on the spot).
    ``produce_group(g)`` returns flush group ``g``'s pooled rows (the
    client forward of that group, in ``sfpl_round``) — or, under sub-mesh
    plans, the whole pool (each exchange is confined to its slice by the
    plan's ``axis_index_groups``). The filled slot's exchange is ISSUED
    before the next group's rows are produced and COMPLETED after —
    issue(k) and produce(k+1) share no data dependence, so the all_to_all
    overlaps the next group's compute under a latency-hiding schedule;
    sub-mesh collectives additionally run on DISJOINT shard slices, so
    every in-flight group can progress simultaneously. The final
    in-flight slot is DRAINED after the loop (the epilogue
    tests/test_streaming.py property-checks: the last flush group is
    never dropped).

    ``skip`` (optional per-group bools — elastic participation) marks
    flush groups whose clients ALL dropped this epoch: their rows pass
    through unexchanged (every row is masked downstream) and the pipeline
    spends no collective on them. Groups with ANY survivor still run
    their full exchange — absent clients' rows travel and are masked.

    Returns the shuffled pool — row for row equal to
    ``collector.permute(pool, perm)`` on the synchronous strategy.
    """
    if not isinstance(prep, PreparedPerm):
        prep = collector.prepare(prep, n)
    bounds = collector.group_bounds(n)
    parts, slot = [], None
    for g in range(len(bounds)):
        ticket = passthrough = None
        if slot is not None:
            if skip and skip[g - 1]:
                passthrough = slot
            else:
                ticket = collector.issue(slot, prep, g - 1)
        rows = produce_group(g)
        if ticket is not None:
            parts.append(collector.complete(ticket))
        elif passthrough is not None:
            parts.append(passthrough)
        slot = rows
    # drain epilogue: the last filled buffer is still in flight
    last = len(bounds) - 1
    if skip and skip[last]:
        parts.append(slot)
    else:
        parts.append(collector.complete(collector.issue(slot, prep, last)))
    return collector.assemble(parts, prep, n)


# --------------------------------------------------------------------------
# shared step pieces

def make_client_update(split, opt_c):
    """Per-client local backprop + optimizer step given routed-back dA.

    Built ONCE per epoch (hoisted out of the scan body) and shared by every
    placement, so the engines stay numerically interchangeable by
    construction.
    """
    def client_upd(cp, cbn, copt, x, da, step):
        def f(cp_):
            a, ncs = split.client_fwd(cp_, cbn, x, True, None)
            return a, ncs
        _, vjp, ncs = jax.vjp(f, cp, has_aux=True)
        g_cp = vjp(da)[0]
        cp_new, copt_new = opt_c.update(g_cp, copt, cp, step)
        return cp_new, copt_new, ncs
    return client_upd


# --------------------------------------------------------------------------
# SFPL round (Algorithm 1 + 2), one body for every placement

def sfpl_round(key, st, data, split, opt_c, opt_s, *, num_clients,
               batch_size, bn_mode="cmsd", collector, participation=None):
    """One SFPL epoch: scan over the n // batch_size local batches.

    ``collector`` is the strategy object (``DenseTake`` / ``MeshAllToAll``)
    that realises the global collector; everything else — client forward,
    ONE server update over the pooled shuffled stack, gradient routing,
    local client updates, epoch-end ClientFedServer — is placement-
    agnostic. ``bn_mode`` selects the paper's aggregation variants:
    "cmsd" excludes BatchNorm from ClientFedServer, "rmsd" aggregates it.

    ``participation`` (optional bool mask, ``(num_clients,)`` for the
    whole epoch or ``(steps, num_clients)`` per step) is ELASTIC
    PARTICIPATION: absent clients' rows stay in the pool for static
    shapes but are masked out of the server update exactly — activations
    zeroed through ``jnp.where`` (exact zero cotangents), labels dropped
    to the loss's ignore index (the loss means over surviving rows), BN
    batch statistics weighted over valid rows only — their local updates
    are gated back to the pre-step state, and the epoch-end
    ClientFedServer averages over (and broadcasts to) the participants
    only. The trajectory therefore matches a dense run on just the
    surviving clients; the differential tests pin it at <= 1e-5. A
    STATIC epoch mask additionally lets the streamed pipeline skip the
    collective of any flush group whose clients all dropped (the mask
    must be concrete at trace time for that fast path; traced masks
    drain every group). The mask must keep >= 1 survivor per flush group
    — ``repro.core.collector.check_participation`` validates this
    eagerly on the host-side entrypoints.
    """
    n_local = data["x"].shape[1]
    steps = n_local // batch_size
    n_pool = num_clients * batch_size
    client_upd = make_client_update(split, opt_c)
    streamed = getattr(collector, "pipelined", False)
    # sub-mesh routing resolves eagerly (it only depends on the layout):
    # under it the client forward is NOT re-cut per group — each shard's
    # clients already are its groups' rows — so the full vmap runs once
    # and the per-group collectives pipeline over the pool
    submesh = streamed and collector.submesh_slices(n_pool) is not None
    cgroups = (collector.client_groups()
               if streamed and not submesh else None)

    part = part_static = None
    if participation is not None:
        if not isinstance(participation, jax.core.Tracer):
            part_static = np.asarray(participation).astype(bool)
        part = jnp.asarray(participation).astype(bool)
        if part.ndim not in (1, 2) or part.shape[-1] != num_clients:
            raise ValueError(
                f"participation mask must have shape ({num_clients},) or "
                f"(steps, {num_clients}); got {part.shape}")
    per_step_part = part is not None and part.ndim == 2
    skip = None
    if streamed and part_static is not None and part_static.ndim == 1:
        skip = tuple(not part_static[c0:c1].any()
                     for c0, c1 in collector.client_groups())
        if not any(skip):
            skip = None

    def one_step(carry, idx):
        st, key = carry
        key, kperm = jax.random.split(key)
        xb = jax.lax.dynamic_slice_in_dim(data["x"], idx * batch_size,
                                          batch_size, axis=1)
        yb = jax.lax.dynamic_slice_in_dim(data["y"], idx * batch_size,
                                          batch_size, axis=1)
        y_pool = yb.reshape((n_pool,))
        perm = collector.make_perm(kperm, n_pool)
        # routing metadata built ONCE per step from the replicated perm;
        # the label permute, activation permute, backward exchange, and
        # (streamed) route_back all reuse it
        prep = collector.prepare(perm, n_pool)
        y_shuf = (collector.permute(y_pool, prep, skip=skip) if streamed
                  else collector.permute(y_pool, prep))
        mask_c = valid_shuf = None
        if part is not None:
            mask_c = part[idx] if per_step_part else part
            # client-major row mask through the SAME permutation as the
            # pool; perm is replicated, so this is a local gather
            valid_shuf = jnp.take(jnp.repeat(mask_c, batch_size), perm)
        fwd = lambda cp, cs, x: split.client_fwd(cp, cs, x, True, None)

        def srv_loss_on(sp, a_shuf):
            if valid_shuf is None:
                loss, (nss, _) = split.server_loss(sp, st["sbn"], a_shuf,
                                                   y_shuf, True, None)
            else:
                loss, (nss, _) = split.server_loss(sp, st["sbn"], a_shuf,
                                                   y_shuf, True, None,
                                                   valid=valid_shuf)
            return loss, nss

        if streamed and submesh:
            # sub-mesh streaming: the full client vmap IS the per-group
            # forward — each shard computes only its own clients, and a
            # group's clients live exactly on its owning slice — so the
            # pool assembles in home layout once and the two-slot
            # pipeline runs the per-group DENSE collectives over it,
            # each confined to its slice by the plan's axis_index_groups
            # (disjoint slices: all in-flight groups progress at once).
            A, ncbn = jax.vmap(fwd)(st["cp"], st["cbn"], xb)
            a_pool = A.reshape((n_pool,) + A.shape[2:])
            a_shuf = streamed_shuffle(collector, prep, n_pool,
                                      lambda g: a_pool, skip=skip)
            (loss, nsbn), (g_sp, g_shuf) = jax.value_and_grad(
                srv_loss_on, argnums=(0, 1), has_aux=True)(
                st["sp"], a_shuf)
            g_pool = collector.route_back(g_shuf, prep, n_pool, skip=skip)
        elif streamed:
            # 1+2+3 pipelined: the client forward runs flush group by
            # flush group, and each filled group's all_to_all is in
            # flight while the next group computes (two-slot pipeline,
            # drained after the last group). The shuffled pool is
            # assembled outside the loss, so the de-shuffle is the
            # strategy's explicit inverse-perm exchange (route_back) —
            # move-for-move what autodiff emits on the sync path.
            A_parts, bn_parts = [], []

            def produce_group(g):
                c0, c1 = cgroups[g]
                sl = lambda t: jax.tree_util.tree_map(
                    lambda a: a[c0:c1], t)
                A_g, ncbn_g = jax.vmap(fwd)(sl(st["cp"]), sl(st["cbn"]),
                                            xb[c0:c1])
                A_parts.append(A_g)
                bn_parts.append(ncbn_g)
                return A_g.reshape((-1,) + A_g.shape[2:])

            a_shuf = streamed_shuffle(collector, prep, n_pool,
                                      produce_group, skip=skip)
            A = _concat_parts(A_parts)
            ncbn = jax.tree_util.tree_map(
                lambda *xs: _concat_parts(list(xs)), *bn_parts)
            (loss, nsbn), (g_sp, g_shuf) = jax.value_and_grad(
                srv_loss_on, argnums=(0, 1), has_aux=True)(
                st["sp"], a_shuf)
            g_pool = collector.route_back(g_shuf, prep, n_pool, skip=skip)
        else:
            # 1. client forward, parallel over the (possibly sharded)
            # client axis
            A, ncbn = jax.vmap(fwd)(st["cp"], st["cbn"], xb)

            # 2. global collector: pool client-major (rows inherit the
            # client sharding, if any) and shuffle per the strategy
            a_pool = A.reshape((n_pool,) + A.shape[2:])

            # 3. ONE server update on the shuffled stack. Differentiating
            # w.r.t. the PRE-shuffle pool makes autodiff emit the
            # de-shuffle (dense scatter or the backward-plan exchange):
            # g_pool arrives already routed back to source clients.
            def srv_loss(sp, a_pool):
                return srv_loss_on(sp, collector.permute(a_pool, prep))
            (loss, nsbn), (g_sp, g_pool) = jax.value_and_grad(
                srv_loss, argnums=(0, 1), has_aux=True)(st["sp"], a_pool)
        sp_new, sopt_new = opt_s.update(g_sp, st["sopt"], st["sp"],
                                        st["step"])

        # 4. client backprop, parallel (dA is pooled like A)
        dA = g_pool.reshape(A.shape)
        cp_new, copt_new, ncbn2 = jax.vmap(
            lambda cp, cbn, copt, x, da: client_upd(cp, cbn, copt, x, da,
                                                    st["step"]))(
            st["cp"], ncbn, st["copt"], xb, dA)
        if mask_c is not None:
            # Absent clients take NO local step: their activation grads
            # are already exact zeros, but the optimizer would still move
            # params (weight decay, momentum decay) and the forward still
            # advanced BN running stats — gate all three back to the
            # pre-step values so they match a run they never joined.
            gate = lambda new, old: jax.tree_util.tree_map(
                lambda nl, ol: jnp.where(
                    mask_c.reshape((-1,) + (1,) * (nl.ndim - 1)), nl, ol),
                new, old)
            cp_new = gate(cp_new, st["cp"])
            copt_new = gate(copt_new, st["copt"])
            ncbn2 = gate(ncbn2, st["cbn"])

        st = dict(st, cp=cp_new, cbn=ncbn2, sp=sp_new, sbn=nsbn,
                  copt=copt_new, sopt=sopt_new, step=st["step"] + 1)
        return (st, key), loss

    (st, _), losses = jax.lax.scan(one_step, (st, key), jnp.arange(steps))

    # 5. ClientFedServer: FedAvg across the client axis (an all-reduce when
    # sharded); BN treatment per bn_mode. Under elastic participation the
    # average runs over the epoch's participants only and is broadcast to
    # every client — absent clients rejoin on the fresh global model,
    # while their (excluded) local BN stays theirs.
    exclude = bn_mode == "cmsd"
    w = None
    if part is not None:
        epoch_mask = part if part.ndim == 1 else part.any(axis=0)
        w = epoch_mask.astype(jnp.float32)
    st = dict(st, cp=fedavg(st["cp"], weights=w, exclude_bn=exclude),
              cbn=aggregate_bn_state(st["cbn"], aggregate=not exclude,
                                     weights=w))
    return st, losses


# --------------------------------------------------------------------------
# SFLv2 round (baseline under study), one body for every placement

def sflv2_round(key, st, data, split, opt_c, opt_s, *, num_clients,
                batch_size, aggregate_bn=True, placement=SINGLE):
    """One SFLv2 epoch: clients visited SEQUENTIALLY in random order — this
    catastrophic-forgetting structure is the object of study and is never
    parallelized. ``placement`` shards the per-client batch axis instead,
    so the server-side stream (the scaling bottleneck) runs data-parallel
    while the visitation order is bit-for-bit preserved."""
    n_local = data["x"].shape[1]
    steps = n_local // batch_size
    order = jax.random.permutation(key, num_clients)

    def per_client(carry, k):
        st = carry
        cp_k = jax.tree_util.tree_map(lambda a: a[k], st["cp"])
        cbn_k = jax.tree_util.tree_map(lambda a: a[k], st["cbn"])
        copt_k = jax.tree_util.tree_map(lambda a: a[k], st["copt"])
        xk = data["x"][k]
        yk = data["y"][k]

        def per_batch(inner, idx):
            cp, cbn, copt, sp, sbn, sopt, step = inner
            xb = jax.lax.dynamic_slice_in_dim(xk, idx * batch_size,
                                              batch_size, axis=0)
            yb = jax.lax.dynamic_slice_in_dim(yk, idx * batch_size,
                                              batch_size, axis=0)
            xb, yb = placement.constrain_batch((xb, yb))

            def f(cp_):
                a, ncs = split.client_fwd(cp_, cbn, xb, True, None)
                return a, ncs
            A, vjp, ncbn = jax.vjp(f, cp, has_aux=True)

            def srv_loss(sp_, a):
                loss, (nss, _) = split.server_loss(sp_, sbn, a, yb, True,
                                                   None)
                return loss, nss
            (loss, nsbn), (g_sp, g_a) = jax.value_and_grad(
                srv_loss, argnums=(0, 1), has_aux=True)(sp, A)
            sp_new, sopt_new = opt_s.update(g_sp, sopt, sp, step)
            g_cp = vjp(g_a)[0]
            cp_new, copt_new = opt_c.update(g_cp, copt, cp, step)
            return (cp_new, ncbn, copt_new, sp_new, nsbn, sopt_new,
                    step + 1), loss

        inner0 = (cp_k, cbn_k, copt_k, st["sp"], st["sbn"], st["sopt"],
                  st["step"])
        inner, losses = jax.lax.scan(per_batch, inner0, jnp.arange(steps))
        cp_k, cbn_k, copt_k, sp, sbn, sopt, step = inner
        put = lambda t, v: jax.tree_util.tree_map(
            lambda a, b: a.at[k].set(b), t, v)
        st = dict(st, cp=put(st["cp"], cp_k), cbn=put(st["cbn"], cbn_k),
                  copt=put(st["copt"], copt_k), sp=sp, sbn=sbn, sopt=sopt,
                  step=step)
        return st, losses

    st, losses = jax.lax.scan(per_client, st, order)
    st = dict(st, cp=fedavg(st["cp"], exclude_bn=False),
              cbn=aggregate_bn_state(st["cbn"], aggregate=aggregate_bn))
    return st, losses
