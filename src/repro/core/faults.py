"""Deterministic fault injection for SFPL training runs.

A :class:`FaultPlan` is a SEEDED schedule of the three failure modes the
resource-constrained IoT setting exhibits (SplitFed 2004.12088 §V; survey
2308.13157): client dropouts, client stragglers, and whole-process kills.
Every draw derives from ``(seed, epoch)`` through a fresh
``np.random.default_rng``, so any process — or a test re-running the
schedule after a crash — reconstructs the identical fault sequence
without shared state. That determinism is what lets the multi-host
harness SIGKILL a worker mid-epoch and still compare the resumed run
against an uninterrupted oracle at 1e-5.

The plan is pure description: :meth:`participation` returns the epoch's
surviving-client mask (and how long a waiting host would stall), and
:meth:`maybe_kill` is the one effectful method — the scheduled process
SIGKILLs ITSELF, the honest simulation of a powered-off worker (no
cleanup handlers, no flushed buffers).
"""
from __future__ import annotations

import dataclasses
import os
import signal
from typing import Optional

import numpy as np

from repro.core.collector import flush_group_sizes


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded per-epoch schedule of dropouts, stragglers, process kills.

    ``drop_rate`` / ``straggler_rate`` are per-(epoch, client)
    probabilities; a straggler answers after ``straggler_delay`` seconds.
    ``kill_process``/``kill_epoch`` schedule one SIGKILL: process
    ``kill_process`` dies at the start of epoch ``kill_epoch`` (mid-run,
    after earlier epochs' checkpoints exist).
    """
    num_clients: int
    seed: int = 0
    drop_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_delay: float = 1.0
    kill_process: Optional[int] = None
    kill_epoch: Optional[int] = None

    def _rng(self, epoch, salt):
        return np.random.default_rng((self.seed, int(epoch), salt))

    def available(self, epoch):
        """Bool mask: client did NOT drop out this epoch."""
        return self._rng(epoch, 0).random(self.num_clients) >= self.drop_rate

    def delays(self, epoch):
        """Per-client response delay in seconds (0 for prompt clients)."""
        stragglers = (self._rng(epoch, 1).random(self.num_clients)
                      < self.straggler_rate)
        return np.where(stragglers, float(self.straggler_delay), 0.0)

    def participation(self, epoch, *, straggler_timeout=None):
        """The epoch's ``(mask, wait_seconds)`` under the straggler policy.

        ``straggler_timeout=None`` is the WAIT policy: every available
        client participates and the host stalls for the slowest
        straggler's delay. A finite timeout is DROP-AND-MASK: clients
        slower than the timeout are masked out with the dropouts and the
        host waits at most the timeout (only spent if someone straggles
        within it).
        """
        mask = self.available(epoch)
        delays = np.where(mask, self.delays(epoch), 0.0)
        if straggler_timeout is None:
            return mask, float(delays.max(initial=0.0))
        mask = mask & (delays <= float(straggler_timeout))
        waited = np.where(mask, delays, 0.0)
        return mask, float(waited.max(initial=0.0))

    def should_kill(self, process_id, epoch):
        return (self.kill_process is not None
                and process_id == self.kill_process
                and epoch == self.kill_epoch)

    def maybe_kill(self, process_id, epoch):
        """SIGKILL the calling process if the schedule says so — no Python
        teardown, no atexit, no flushing: the process is simply gone, like
        a powered-off IoT gateway."""
        if self.should_kill(process_id, epoch):
            os.kill(os.getpid(), signal.SIGKILL)


def ensure_group_survivor(mask, num_clients, *, alpha=1.0):
    """Graceful degradation of a random dropout draw: revive the
    lowest-index client of any flush group the draw emptied, so the mask
    always satisfies ``check_participation``'s >= 1-survivor-per-group
    invariant. Returns ``(mask, revived_client_indices)`` — the driver
    logs the revivals instead of crashing the round."""
    mask = np.asarray(mask, dtype=bool).copy()
    if mask.shape != (num_clients,):
        raise ValueError(
            f"mask must have shape ({num_clients},); got {mask.shape}")
    revived, start = [], 0
    for c in flush_group_sizes(num_clients, alpha):
        if not mask[start:start + c].any():
            mask[start] = True
            revived.append(start)
        start += c
    return mask, revived
