from repro.sharding.rules import (
    param_shardings, batch_shardings, state_shardings, DP_AXES, TP_AXIS,
    FSDP_AXIS)
