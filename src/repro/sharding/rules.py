"""Sharding rules: param/batch/state pytrees -> PartitionSpecs.

Scheme ("2D FSDP + TP", MaxText-style):
  * batch / client axis      -> ("pod", "data")  (SFPL: data shards = client
                                groups; the collector all-to-all runs here)
  * tensor-parallel dims     -> "model" (attention heads, MLP hidden,
                                MoE experts, vocab)
  * FSDP dim                 -> "data" (the remaining large param dim;
                                params are replicated across pods — weight
                                all-gathers stay on intra-pod ICI)
  * layer-scan leading dims  -> replicated

Every assignment is divisibility-checked against the mesh: a dim that the
axis does not divide falls back to replicated (recorded by the dry-run so
the roofline report can flag it).
"""
from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P

DP_AXES = ("pod", "data")    # batch axis ("pod" absent on single-pod mesh)
TP_AXIS = "model"
FSDP_AXIS = "data"


def _names(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


# (regex on the joined path, spec template applied to the TRAILING dims).
# Templates use tokens: "tp" -> model axis, "fsdp" -> data axis, None.
import os
if os.environ.get("REPRO_MOE_EP") == "data":
    _MOE_RULES = [
        (r"moe/(wi|wg)$",                    ("fsdp", None, "tp")),
        (r"moe/wo$",                         ("fsdp", "tp", None)),
    ]
else:
    _MOE_RULES = [
        (r"moe/(wi|wg)$",                    ("tp", "fsdp", None)),
        (r"moe/wo$",                         ("tp", None, "fsdp")),
    ]

_RULES = _MOE_RULES + [
    (r"router/w$",                           (None, None)),
    (r"(wq|wk|wv)/w$",                       ("fsdp", "tp")),
    (r"(embed|pos_embed)/table$",            ("tp", "fsdp")),
    (r"unembed/w$",                          ("fsdp", "tp")),
    (r"(wo|down|ff_down)/w$",                ("tp", "fsdp")),
    (r"(wi|wg|up|up_main|up_gate|ff_up)/w$", ("fsdp", "tp")),
    (r"w_[rizfo]/w$",                        ("fsdp", "tp")),
    (r"(wq|wk|wv)/b$",                       ("tp",)),
    (r"gates/w$",                            ("fsdp", None)),
    (r"lambda$",                             ("tp",)),
]


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve(template, shape, sizes, has_pod, fsdp=True):
    spec = []
    for tok, dim in zip(template, shape):
        if tok is None:
            spec.append(None)
        elif tok == "tp":
            spec.append(TP_AXIS if dim % sizes[TP_AXIS] == 0 else None)
        elif tok == "fsdp":
            spec.append(FSDP_AXIS if fsdp and dim % sizes[FSDP_AXIS] == 0
                        else None)
        else:
            spec.append(None)
    return spec


def spec_for_param(path, leaf_shape, mesh, *, fsdp=True):
    """PartitionSpec for one param leaf."""
    name = _names(path)
    sizes = _axis_sizes(mesh)
    has_pod = "pod" in sizes
    # xlstm block-diagonal qkv: trailing (num_blocks, bs, bs) with tiny bs
    if re.search(r"(wq|wk|wv)/w$", name) and len(leaf_shape) >= 3 \
            and leaf_shape[-1] == leaf_shape[-2] and leaf_shape[-1] <= 16:
        lead = len(leaf_shape) - 3
        spec = [None] * lead + _resolve(("tp", None, None),
                                        leaf_shape[lead:], sizes, has_pod,
                                        fsdp)
        return P(*spec)
    for pattern, template in _RULES:
        if not re.search(pattern, name):
            continue
        nd = len(template)
        if len(leaf_shape) < nd:
            continue
        lead = len(leaf_shape) - nd
        spec = [None] * lead + _resolve(template, leaf_shape[lead:], sizes,
                                        has_pod, fsdp)
        return P(*spec)
    return P()   # replicate (norms, biases, convs, small tensors)


def param_shardings(param_shapes, mesh, *, fsdp=True):
    """Map a pytree of ShapeDtypeStructs -> pytree of NamedSharding.

    ``fsdp=False`` replicates the FSDP dims over "data" (pure TP) — a perf
    experiment knob: trades param memory for fewer weight collectives."""
    def one(path, leaf):
        return jax.sharding.NamedSharding(
            mesh, spec_for_param(path, leaf.shape, mesh, fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(one, param_shapes)


# --------------------------------------------------------------------------
# batch / decode-state shardings

def _dp(mesh):
    sizes = _axis_sizes(mesh)
    return tuple(a for a in DP_AXES if a in sizes)


def batch_shardings(batch_shapes, mesh):
    """Shard the leading batch dim over ("pod","data"); if batch is not
    divisible (long_500k batch=1), shard the sequence dim over "data"."""
    sizes = _axis_sizes(mesh)
    dp = _dp(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]

    def one(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        if len(shape) >= 1 and shape[0] % dp_size == 0 and shape[0] > 1:
            spec[0] = dp
        elif len(shape) >= 2 and shape[1] % sizes["data"] == 0:
            spec[1] = "data"      # sequence sharding fallback
        return jax.sharding.NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def state_shardings(state_shapes, mesh):
    """Decode caches / recurrent states.

    KV cache (B, slots, K, D): batch over dp when divisible; otherwise the
    slots axis is sharded over "data" (sequence-sharded cache — distributed
    "ring decode"). kv-head dim over "model" when divisible. Leading stacked
    layer dims are skipped automatically (detected as dims preceding the
    recognised suffix)."""
    sizes = _axis_sizes(mesh)
    dp = _dp(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]

    def one(path, leaf):
        name = _names(path)
        shape = leaf.shape
        spec = [None] * len(shape)
        # find batch-like dim: for cache leaves under k/v/pos the layout is
        # ([layers...], B, slots, K, D) / ([layers...], B, slots)
        if re.search(r"(^|/)(k|v)$", name) and len(shape) >= 4:
            b, sl, kh = len(shape) - 4, len(shape) - 3, len(shape) - 2
            if shape[b] % dp_size == 0 and shape[b] > 1:
                spec[b] = dp
            elif shape[sl] % sizes["data"] == 0:
                spec[sl] = "data"
            if shape[kh] % sizes[TP_AXIS] == 0:
                spec[kh] = TP_AXIS
            elif spec[sl] is None and shape[sl] % sizes[TP_AXIS] == 0:
                # kv heads not TP-divisible: shard cache slots over model
                spec[sl] = TP_AXIS
        elif re.search(r"(^|/)pos$", name) and len(shape) >= 2:
            b, sl = len(shape) - 2, len(shape) - 1
            if shape[b] % dp_size == 0 and shape[b] > 1:
                spec[b] = dp
            if shape[sl] % sizes[TP_AXIS] == 0:
                spec[sl] = TP_AXIS
            elif spec[b] is None and shape[sl] % sizes["data"] == 0:
                spec[sl] = "data"
        else:
            # recurrent states ([groups], B, ...): first dp-divisible dim
            # is the batch; everything else replicated (states are small)
            for i, d in enumerate(shape):
                if d > 1 and d % dp_size == 0:
                    spec[i] = dp
                    break
        return jax.sharding.NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, state_shapes)
