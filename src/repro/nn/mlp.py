"""Feed-forward blocks: SwiGLU / GeGLU / plain GELU-MLP (relu for resnet heads)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import dense_init, dense_apply

ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),   # nemotron/minitron
}


def mlp_init(key, d_model, d_ff, *, gated=True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d_model, d_ff, use_bias=False, dtype=dtype),
        "wo": dense_init(ks[2], d_ff, d_model, use_bias=False, dtype=dtype),
    }
    if gated:
        p["wg"] = dense_init(ks[1], d_model, d_ff, use_bias=False, dtype=dtype)
    return p


def mlp_apply(params, x, *, act="silu"):
    """SwiGLU (act=silu) / GeGLU (act=gelu) when 'wg' present, else plain MLP."""
    h = dense_apply(params["wi"], x)
    if "wg" in params:
        h = ACTS[act](dense_apply(params["wg"], x)) * h
    else:
        h = ACTS[act](h)
    return dense_apply(params["wo"], h)
