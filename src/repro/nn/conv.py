"""2-D convolution (NHWC, HWIO) for the ResNet family."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.init import he_normal, zeros_init


def conv2d_init(key, in_ch, out_ch, kernel, *, use_bias=False,
                dtype=jnp.float32):
    kh, kw_ = (kernel, kernel) if isinstance(kernel, int) else kernel
    kw, kb = jax.random.split(key)
    p = {"w": he_normal(kw, (kh, kw_, in_ch, out_ch), dtype=dtype,
                        in_axis=2, out_axis=3)}
    if use_bias:
        p["b"] = zeros_init(kb, (out_ch,), dtype=dtype)
    return p


def conv2d_apply(params, x, *, stride=1, padding="SAME", compute_dtype=None):
    w = params["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    strides = (stride, stride) if isinstance(stride, int) else stride
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y
