"""Dense and embedding layers."""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.nn.init import lecun_normal, normal_init, zeros_init


def dense_init(key, in_dim, out_dim, *, use_bias=True, dtype=jnp.float32,
               init=lecun_normal):
    kw, kb = jax.random.split(key)
    p = {"w": init(kw, (in_dim, out_dim), dtype=dtype)}
    if use_bias:
        p["b"] = zeros_init(kb, (out_dim,), dtype=dtype)
    return p


def dense_apply(params, x, *, compute_dtype=None):
    w = params["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def embedding_init(key, vocab, dim, *, dtype=jnp.float32, stddev=0.02):
    return {"table": normal_init(key, (vocab, dim), stddev=stddev, dtype=dtype)}


def embedding_apply(params, ids, *, compute_dtype=None):
    t = params["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def embedding_attend(params, x, *, compute_dtype=None):
    """Tied-unembedding: project features back to vocab logits."""
    t = params["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
        x = x.astype(compute_dtype)
    return x @ t.T
