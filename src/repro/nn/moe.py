"""Mixture-of-experts layer (top-1 routing, Llama-4 style).

TPU/SPMD-native dispatch (DESIGN.md §4): tokens are routed **locally per
data-parallel shard** — the token axis is reshaped to (dp_groups, T_local)
so every sort/rank/gather runs along the local axis with batch dims, which
XLA partitions cleanly (no global sort, no scatter):

  1. per-row argsort tokens by expert id (vectorized sort)
  2. per-(row, expert) counts -> exclusive-cumsum offsets
  3. dispatch = take_along_axis gather of sorted tokens into a dense
     (dp, E, C, d) buffer (C = local capacity)   [gather-only, no scatter]
  4. expert SwiGLU einsum with the expert dim sharded over "model"
     (expert parallelism)
  5. combine = gather back by (expert, rank), unsort, gate-scale.

Llama-4 details honoured: top-1 router, sigmoid gate on the routed expert's
output, always-on shared expert. Local capacity (tokens never cross data
shards) matches deployed MoE systems' behaviour.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.init import lecun_normal, normal_init
from repro.nn.mlp import mlp_init, mlp_apply, ACTS


def moe_init(key, d_model, d_ff, num_experts, *, shared_expert=True,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": normal_init(ks[0], (d_model, num_experts),
                                    stddev=0.02, dtype=jnp.float32)},
        "wi": lecun_normal(ks[1], (num_experts, d_model, d_ff), dtype=dtype,
                           in_axis=1, out_axis=2),
        "wg": lecun_normal(ks[2], (num_experts, d_model, d_ff), dtype=dtype,
                           in_axis=1, out_axis=2),
        "wo": lecun_normal(ks[3], (num_experts, d_ff, d_model), dtype=dtype,
                           in_axis=1, out_axis=2),
    }
    if shared_expert:
        p["shared"] = mlp_init(ks[4], d_model, d_ff, gated=True, dtype=dtype)
    return p


def _constrain(x, mesh_axes, spec_template):
    """Best-effort sharding constraint (no-op without mesh_axes)."""
    if not mesh_axes:
        return x
    from jax.sharding import PartitionSpec as P
    sizes = dict(mesh_axes)
    spec = []
    for tok, dim in zip(spec_template, x.shape):
        if tok is None:
            spec.append(None)
            continue
        axes = tok if isinstance(tok, tuple) else (tok,)
        prod = 1
        for a in axes:
            prod *= sizes.get(a, 1)
        spec.append(tok if dim % prod == 0 and dim >= prod else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _rank_in_sorted_groups(sorted_eid):
    """sorted_eid: (G, T) ascending. rank of each element within its run."""
    T = sorted_eid.shape[-1]
    idx = jnp.arange(T, dtype=jnp.int32)
    new_run = jnp.concatenate(
        [jnp.ones_like(sorted_eid[..., :1], bool),
         sorted_eid[..., 1:] != sorted_eid[..., :-1]], axis=-1)
    run_start = jax.lax.cummax(jnp.where(new_run, idx, 0),
                               axis=sorted_eid.ndim - 1)
    return idx - run_start


def moe_apply(params, x, *, num_experts, capacity_factor=1.25, act="silu",
              gate="sigmoid", return_aux=True, dp_groups=1, mesh_axes=None):
    """x: (B, S, d). Returns (y, aux)."""
    B, S, d = x.shape
    E = num_experts
    T = B * S
    G = dp_groups if T % dp_groups == 0 else 1
    Tl = T // G
    C = int(max(1, round(Tl / E * capacity_factor)))

    dp_tok = None
    if mesh_axes:
        dp = tuple(a for a, _ in mesh_axes if a != "model")
        dp_tok = dp or None

    xt = x.reshape(G, Tl, d)
    xt = _constrain(xt, mesh_axes, (dp_tok, None, "model"))
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"]["w"])            # (G, Tl, E)
    expert_id = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if gate == "sigmoid":        # llama4: sigmoid of the chosen logit
        gate_val = jax.nn.sigmoid(jnp.max(logits, axis=-1))
    else:
        gate_val = jnp.max(jax.nn.softmax(logits, axis=-1), axis=-1)

    # 1. local sort by expert
    sort_idx = jnp.argsort(expert_id, axis=-1)            # (G, Tl)
    sorted_eid = jnp.take_along_axis(expert_id, sort_idx, axis=-1)
    x_sorted = jnp.take_along_axis(xt, sort_idx[..., None], axis=1)
    x_sorted = _constrain(x_sorted, mesh_axes, (dp_tok, None, "model"))

    # 2. per-expert counts -> offsets into the sorted order
    counts = jnp.sum(jax.nn.one_hot(expert_id, E, dtype=jnp.int32),
                     axis=1)                               # (G, E)
    offsets = jnp.cumsum(counts, axis=-1) - counts         # exclusive

    # 3. gather-only dispatch into (G, E, C, d)
    pos = jnp.arange(C, dtype=jnp.int32)
    gather_idx = offsets[..., None] + pos                  # (G, E, C)
    valid = pos[None, None] < jnp.minimum(counts, C)[..., None]
    gather_idx = jnp.clip(gather_idx, 0, Tl - 1)
    buf = jnp.take_along_axis(
        x_sorted, gather_idx.reshape(G, E * C)[..., None], axis=1)
    buf = buf.reshape(G, E, C, d) * valid[..., None].astype(x.dtype)
    import os as _os
    if _os.environ.get("REPRO_MOE_DISPATCH") == "dshard" and mesh_axes:
        # keep d sharded through the dispatch gather too; the d->E reshard
        # happens right at the expert einsum
        buf = _constrain(buf, mesh_axes, (dp_tok, None, None, "model"))
    else:
        buf = _constrain(buf, mesh_axes, (dp_tok, "model", None, None))

    # 4. expert-parallel SwiGLU
    import os
    if os.environ.get("REPRO_MOE_EP") == "data" and mesh_axes:
        # all-to-all layout: transpose (G, E, C, d) -> (E, G, C, d) with the
        # EXPERT dim on the data axis — each device owns one expert shard
        # and receives all tokens routed to it (textbook MoE a2a).
        buf_t = _constrain(buf.swapaxes(0, 1), mesh_axes,
                           (dp_tok, None, None, None))
        h = jnp.einsum("egcd,edf->egcf", buf_t, params["wi"])
        g = jnp.einsum("egcd,edf->egcf", buf_t, params["wg"])
        h = _constrain(ACTS[act](g) * h, mesh_axes,
                       (dp_tok, None, None, "model"))
        out_t = jnp.einsum("egcf,efd->egcd", h, params["wo"])
        out_t = _constrain(out_t, mesh_axes, (dp_tok, None, None, None))
        out = out_t.swapaxes(0, 1)          # a2a back to token-major
        out = _constrain(out, mesh_axes, (dp_tok, "model", None, None))
    elif os.environ.get("REPRO_MOE_COMBINE", "dshard") == "dshard" and mesh_axes:
        # low-comm combine: after the expert einsums, reshard the feature
        # dim (not the expert dim) over "model" so the combine/unsort
        # gathers stay shard-local; the E->d reshard is one a2a-sized
        # exchange instead of gather+psum crossings.
        h = jnp.einsum("gecd,edf->gecf", buf, params["wi"])
        g = jnp.einsum("gecd,edf->gecf", buf, params["wg"])
        h = ACTS[act](g) * h
        out = jnp.einsum("gecf,efd->gecd", h, params["wo"])
        out = _constrain(out, mesh_axes, (dp_tok, None, None, "model"))
    else:
        h = jnp.einsum("gecd,edf->gecf", buf, params["wi"])
        g = jnp.einsum("gecd,edf->gecf", buf, params["wg"])
        h = ACTS[act](g) * h
        out = jnp.einsum("gecf,efd->gecd", h, params["wo"])
        out = _constrain(out, mesh_axes, (dp_tok, "model", None, None))

    # 5. combine: token at sorted position t sits at (expert, rank)
    rank = _rank_in_sorted_groups(sorted_eid)              # (G, Tl)
    keep = rank < C
    comb_idx = sorted_eid * C + jnp.minimum(rank, C - 1)   # (G, Tl)
    y_sorted = jnp.take_along_axis(
        out.reshape(G, E * C, d), comb_idx[..., None], axis=1)
    y_sorted = y_sorted * keep[..., None].astype(out.dtype)
    inv = jnp.argsort(sort_idx, axis=-1)
    y_routed = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    y_routed = y_routed * gate_val.reshape(G, Tl)[..., None].astype(
        y_routed.dtype)
    y_routed = y_routed.reshape(T, d)

    y = y_routed
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x.reshape(T, d), act=act)
    y = y.reshape(B, S, d).astype(x.dtype)

    if not return_aux:
        return y, None
    aux = {
        "router_logits": logits.reshape(T, E),
        "expert_id": expert_id.reshape(T),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux


def router_load_balance_loss(router_logits, expert_id, num_experts):
    """Switch-transformer load balance loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    p_e = jnp.mean(probs, axis=0)                                   # (E,)
    f_e = jnp.mean(jax.nn.one_hot(expert_id, num_experts), axis=0)  # (E,)
    return num_experts * jnp.sum(f_e * p_e)
