"""Grouped-query attention with RoPE / M-RoPE, qk-norm, sliding windows and a
ring-buffer KV cache.

Three execution paths selected by ``AttnConfig.impl``:
  * ``xla``              — pure jnp einsum attention (the path that lowers in
                           the multi-pod dry-run; XLA SPMD inserts collectives)
  * ``pallas``           — Pallas-TPU flash attention (target hardware)
  * ``pallas_interpret`` — same kernel, interpret mode (CPU validation)

Decode uses a slot-indexed cache: ``cache["pos"]`` records the absolute
position held in each slot (-1 = empty). Global attention uses a cache of
``max_len`` slots; sliding-window attention uses ``window`` slots written
round-robin, which keeps long-context (500k) decode state O(window).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.init import lecun_normal
from repro.nn.linear import dense_init, dense_apply
from repro.nn.norm import rmsnorm_init, rmsnorm_apply
from repro.nn.rope import apply_rope, apply_mrope

NEG_INF = -2.3819763e38  # large negative for bf16-safe masking


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None
    sliding_window: Optional[int] = None  # None = global attention
    use_rope: bool = True
    impl: str = "xla"
    kv_chunk: int = 4096        # online-softmax chunk for the xla path
    mesh_axes: Optional[Tuple[Tuple[str, int], ...]] = None

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


def attention_init(key, cfg: AttnConfig, *, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    H, K, D, M = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": dense_init(ks[0], M, H * D, use_bias=cfg.use_bias, dtype=dtype),
        "wk": dense_init(ks[1], M, K * D, use_bias=cfg.use_bias, dtype=dtype),
        "wv": dense_init(ks[2], M, K * D, use_bias=cfg.use_bias, dtype=dtype),
        "wo": dense_init(ks[3], H * D, M, use_bias=False, dtype=dtype,
                         init=lecun_normal),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(ks[4], D, dtype=dtype)
        p["k_norm"] = rmsnorm_init(ks[5], D, dtype=dtype)
    return p


def init_kv_cache(batch, num_slots, num_kv_heads, head_dim, *,
                  dtype=jnp.bfloat16):
    """num_slots = max_len for global layers, window for SWA layers.

    ``dtype=jnp.int8`` selects the quantized cache: int8 mantissas with
    per-(slot, head) fp16 scales — 2.1x smaller than bf16 (gemma-7b
    decode_32k carries a 1.9 TB global cache; quantization is the
    standard serving fix). Quant/dequant happens at write/read inside
    attention_decode."""
    cache = {
        "k": jnp.zeros((batch, num_slots, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, num_slots, num_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, num_slots), -1, jnp.int32),
    }
    if dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros((batch, num_slots, num_kv_heads),
                                     jnp.float16)
        cache["v_scale"] = jnp.zeros((batch, num_slots, num_kv_heads),
                                     jnp.float16)
    return cache


def _quantize_kv(x):
    """x: (B, 1, K, D) -> (int8 values, fp16 scales (B, 1, K))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def _project_qkv(params, x, cfg: AttnConfig, positions):
    B, S, _ = x.shape
    H, K, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense_apply(params["wq"], x).reshape(B, S, H, D)
    k = dense_apply(params["wk"], x).reshape(B, S, K, D)
    v = dense_apply(params["wv"], x).reshape(B, S, K, D)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q)
        k = rmsnorm_apply(params["k_norm"], k)
    if cfg.use_rope:
        if cfg.mrope_sections is not None:
            # positions: (3, B, S)
            q = apply_mrope(q, positions, theta=cfg.rope_theta,
                            sections=cfg.mrope_sections)
            k = apply_mrope(k, positions, theta=cfg.rope_theta,
                            sections=cfg.mrope_sections)
        else:
            # positions: (B, S)
            q = apply_rope(q, positions, theta=cfg.rope_theta)
            k = apply_rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def _constrain_scores(s, mesh_axes):
    """Shard chunked scores (B, K, G, Sq, ck): batch over dp, Sq over model
    (sequence-parallel attention) when divisible."""
    if not mesh_axes:
        return s
    from jax.sharding import PartitionSpec as P
    sizes = dict(mesh_axes)
    dp = tuple(a for a, _ in mesh_axes if a != "model")
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    tp = sizes.get("model", 1)
    spec = [None] * s.ndim
    if s.shape[0] % dp_size == 0 and s.shape[0] >= dp_size:
        spec[0] = dp
    if s.shape[3] % tp == 0 and s.shape[3] >= tp:
        spec[3] = "model"
    return jax.lax.with_sharding_constraint(s, P(*spec))


def _xla_attention(q, k, v, scale, *, q_pos=None, kv_pos=None, causal=True,
                   window=None, kv_valid=None, kv_chunk=4096,
                   mesh_axes=None):
    """Chunked online-softmax attention (never materializes Sq x Skv).

    q: (B,Sq,H,D); k/v: (B,Skv,K,D). Masking composed per kv-chunk from:
      q_pos/kv_pos (B,S*) absolute positions (causal/window deltas),
      kv_valid (B,Skv) validity (cache slots / cross-attn padding).
    The chunk loop is a python unroll — trip counts stay visible to
    cost_analysis (the Pallas kernel is the real-TPU path; this mirrors its
    memory behaviour so the dry-run numbers are representative).
    """
    B, Sq, H, D = q.shape
    K, Skv = k.shape[2], k.shape[1]
    G = H // K
    q5 = q.reshape(B, Sq, K, G, D)
    ck = min(kv_chunk, Skv)
    nck = -(-Skv // ck)

    m = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l = jnp.zeros((B, K, G, Sq), jnp.float32)
    acc = jnp.zeros((B, K, G, Sq, D), jnp.float32)

    def chunk_step(carry, q5, kj, vj, qp, kp, kvj):
        m, l, acc = carry
        s = jnp.einsum("bqkgd,bskd->bkgqs", q5, kj,
                       preferred_element_type=jnp.float32) * scale
        s = _constrain_scores(s, mesh_axes)
        mask = jnp.ones((B, 1, 1, Sq, kj.shape[1]), bool)
        if qp is not None and kp is not None:
            delta = qp[:, :, None] - kp[:, None]            # (B,Sq,ck)
            dm = delta >= 0 if causal else jnp.ones_like(delta, bool)
            if window is not None:
                dm = dm & (delta < window)
            mask = mask & dm[:, None, None]
        if kvj is not None:
            mask = mask & kvj[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v.dtype), vj,
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    # per-chunk remat: the backward recomputes one chunk's probs at a time
    # (flash-attention memory behaviour; matches the Pallas kernel's bwd).
    if nck > 1 or Sq * Skv > 1 << 22:
        chunk_step = jax.checkpoint(chunk_step)

    if nck <= 2:
        for j in range(nck):
            lo = j * ck
            hi = min(lo + ck, Skv)
            (m, l, acc) = chunk_step(
                (m, l, acc), q5, k[:, lo:hi], v[:, lo:hi],
                q_pos, None if kv_pos is None else kv_pos[:, lo:hi],
                None if kv_valid is None else kv_valid[:, lo:hi])
    else:
        # many chunks: lax.scan so chunk buffers are provably reused.
        # (cost_analysis counts the body once — the roofline module corrects
        # attention FLOPs analytically; see roofline/analysis.py)
        pad = nck * ck - Skv
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvv = (jnp.ones((B, Skv), bool) if kv_valid is None else kv_valid)
        kvv = jnp.pad(kvv, ((0, 0), (0, pad)))
        kpos = (kv_pos if kv_pos is not None
                else jnp.zeros((B, Skv), jnp.int32))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)))
        rs = lambda a: a.reshape((B, nck, ck) + a.shape[2:]).swapaxes(0, 1)
        use_pos = q_pos is not None and kv_pos is not None

        def body(carry, xs):
            kj, vj, kpj, kvj = xs
            carry = chunk_step(carry, q5, kj, vj,
                               q_pos if use_pos else None,
                               kpj if use_pos else None, kvj)
            return carry, None

        (m, l, acc), _ = jax.lax.scan(
            body, (m, l, acc), (rs(kp), rs(vp), rs(kpos), rs(kvv)))

    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).astype(q.dtype)       # (B,K,G,Sq,D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)


def attention_apply(params, x, cfg: AttnConfig, *, positions,
                    causal: bool = True, cache=None, cur_pos=None,
                    return_kv: bool = False, kv_override=None):
    """Full-sequence (train / prefill) attention.

    ``kv_override=(k, v, kv_mask)`` implements cross-attention: q from ``x``,
    fixed k/v (e.g. whisper encoder output), boolean kv_mask (B, Skv) or None.
    Returns ``out`` or ``(out, (k, v))`` when ``return_kv``.
    """
    B, S, _ = x.shape
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if kv_override is not None:
        H, D = cfg.num_heads, cfg.head_dim
        q = dense_apply(params["wq"], x).reshape(B, S, H, D)
        if cfg.qk_norm:
            q = rmsnorm_apply(params["q_norm"], q)
        k, v, kv_mask = kv_override
        out = _xla_attention(q, k, v, scale, causal=False, kv_valid=kv_mask,
                             kv_chunk=cfg.kv_chunk, mesh_axes=cfg.mesh_axes)
        return dense_apply(params["wo"], out.reshape(B, S, -1))

    q, k, v = _project_qkv(params, x, cfg, positions)

    if cfg.impl in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window,
            interpret=(cfg.impl == "pallas_interpret"))
    else:
        pq = positions if positions.ndim == 2 else positions[0]
        out = _xla_attention(q, k, v, scale, q_pos=pq, kv_pos=pq,
                             causal=causal, window=cfg.sliding_window,
                             kv_chunk=cfg.kv_chunk, mesh_axes=cfg.mesh_axes)
    out = dense_apply(params["wo"], out.reshape(B, S, -1))
    if return_kv:
        return out, (k, v)
    return out


def attention_decode(params, x, cfg: AttnConfig, *, cache, cur_pos):
    """One-token decode. x: (B, 1, d_model); cur_pos: scalar int32 OR a
    (B,) vector of per-request positions (continuous batching). Returns
    (out, new_cache)."""
    B = x.shape[0]
    num_slots = cache["k"].shape[1]
    cur_pos = jnp.asarray(cur_pos, jnp.int32)
    per_slot = cur_pos.ndim == 1
    pos_arr = (cur_pos[:, None] if per_slot
               else jnp.full((B, 1), cur_pos, jnp.int32))
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos_arr[None], (3, B, 1))
    else:
        positions = pos_arr
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)

    slot = jnp.mod(pos_arr[:, 0], num_slots)   # (B,) ring / identity
    quantized = cache["k"].dtype == jnp.int8
    if quantized:
        k_store, k_sc = _quantize_kv(k_new)
        v_store, v_sc = _quantize_kv(v_new)
    else:
        k_store, v_store = k_new, v_new
    new_cache = dict(cache)
    if per_slot:
        rows = jnp.arange(B)
        put = lambda buf, val: buf.at[rows, slot].set(
            val[:, 0].astype(buf.dtype))
        new_cache["k"] = put(cache["k"], k_store)
        new_cache["v"] = put(cache["v"], v_store)
        pos = cache["pos"].at[rows, slot].set(pos_arr[:, 0])
        if quantized:
            new_cache["k_scale"] = put(cache["k_scale"], k_sc)
            new_cache["v_scale"] = put(cache["v_scale"], v_sc)
    else:
        upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
            buf, val.astype(buf.dtype), slot[0], axis=1)
        new_cache["k"] = upd(cache["k"], k_store)
        new_cache["v"] = upd(cache["v"], v_store)
        pos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], pos_arr, slot[0], axis=1)
        if quantized:
            new_cache["k_scale"] = upd(cache["k_scale"], k_sc)
            new_cache["v_scale"] = upd(cache["v_scale"], v_sc)
    new_cache["pos"] = pos

    if quantized:
        k = _dequantize_kv(new_cache["k"], new_cache["k_scale"])
        v = _dequantize_kv(new_cache["v"], new_cache["v_scale"])
    else:
        k, v = new_cache["k"], new_cache["v"]

    valid = (pos >= 0) & (pos <= pos_arr)
    if cfg.sliding_window is not None:
        valid = valid & (pos_arr - pos < cfg.sliding_window)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    out = _xla_attention(q, k, v, scale, kv_valid=valid,
                         kv_chunk=cfg.kv_chunk, mesh_axes=cfg.mesh_axes)
    out = dense_apply(params["wo"], out.reshape(B, 1, -1))
    return out, new_cache
