"""Functional neural-network substrate for repro.

No flax/haiku in this environment: layers follow an explicit functional
convention —

    params = <layer>_init(key, ...)          # pytree of jnp arrays
    out    = <layer>_apply(params, x, ...)   # pure function

Stateful layers (BatchNorm) carry a separate ``state`` tree threaded through
apply calls, never hidden inside params.
"""

from repro.nn.init import (
    lecun_normal, he_normal, normal_init, zeros_init, ones_init, uniform_scaling
)
from repro.nn.linear import (
    dense_init, dense_apply, embedding_init, embedding_apply, embedding_attend
)
from repro.nn.conv import conv2d_init, conv2d_apply
from repro.nn.norm import (
    batchnorm_init, batchnorm_apply,
    layernorm_init, layernorm_apply,
    rmsnorm_init, rmsnorm_apply,
)
from repro.nn.rope import rope_freqs, apply_rope, mrope_positions, apply_mrope
from repro.nn.attention import (
    attention_init, attention_apply, init_kv_cache, AttnConfig
)
from repro.nn.mlp import mlp_init, mlp_apply
from repro.nn.moe import moe_init, moe_apply, router_load_balance_loss
