"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE (multimodal RoPE, arXiv:2409.12191): head_dim channels are split into
three sections (temporal, height, width); each section rotates with its own
position id. For pure-text tokens all three ids are equal, recovering 1-D RoPE.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim, *, theta=10000.0):
    """Inverse frequencies, shape (head_dim//2,) fp32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def _rotate(x, angles):
    """x: (..., head_dim), angles: broadcastable (..., head_dim//2)."""
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(x, positions, *, theta=10000.0):
    """x: (B, S, H, D); positions: (B, S) int32."""
    inv = rope_freqs(x.shape[-1], theta=theta)            # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, D/2)
    return _rotate(x, ang[:, :, None, :])                 # bcast over heads


def mrope_positions(positions_3d):
    """Identity helper kept for API symmetry; positions_3d: (3, B, S)."""
    return positions_3d


def apply_mrope(x, positions_3d, *, theta=1000000.0, sections=(16, 24, 24)):
    """x: (B, S, H, D); positions_3d: (3, B, S) int32 (t, h, w ids).

    ``sections`` are half-dim channel counts per (t,h,w); must sum to D/2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(x.shape[-1], theta=theta)            # (D/2,)
    # per-channel section id: 0,0,..,1,1,..,2,2..
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)         # (D/2,)
    pos = positions_3d.astype(jnp.float32)                # (3, B, S)
    # angles: pick the section's position stream per channel (one-hot select)
    ang_all = pos[..., None] * inv                        # (3, B, S, D/2)
    onehot = (jnp.arange(3)[:, None] == sec_id[None, :]).astype(jnp.float32)  # (3, D/2)
    ang = jnp.einsum("kbsd,kd->bsd", ang_all, onehot)     # (B, S, D/2)
    return _rotate(x, ang[:, :, None, :])
