"""Parameter initializers (fan-based, matching common framework defaults)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _fans(shape, in_axis=-2, out_axis=-1):
    if len(shape) < 1:
        return 1, 1
    if len(shape) == 1:
        return shape[0], shape[0]
    receptive = 1
    for i, s in enumerate(shape):
        if i not in (in_axis % len(shape), out_axis % len(shape)):
            receptive *= s
    return shape[in_axis] * receptive, shape[out_axis] * receptive


def lecun_normal(key, shape, dtype=jnp.float32, in_axis=-2, out_axis=-1):
    fan_in, _ = _fans(shape, in_axis, out_axis)
    std = math.sqrt(1.0 / max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def he_normal(key, shape, dtype=jnp.float32, in_axis=-2, out_axis=-1):
    fan_in, _ = _fans(shape, in_axis, out_axis)
    std = math.sqrt(2.0 / max(fan_in, 1))
    return (jax.random.normal(key, shape) * std).astype(dtype)


def normal_init(key, shape, stddev=0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def zeros_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def uniform_scaling(key, shape, scale=1.0, dtype=jnp.float32):
    fan_in, _ = _fans(shape)
    lim = scale * math.sqrt(3.0 / max(fan_in, 1))
    return jax.random.uniform(key, shape, minval=-lim, maxval=lim).astype(dtype)
