"""Normalization layers.

BatchNorm is central to the paper: its running statistics ("RMSD") vs
current-batch statistics ("CMSD") distinction at inference, and its exclusion
from FedAvg aggregation, are half of SFPL's contribution. Running statistics
live in a separate ``state`` tree so aggregation policies can treat
parameters and statistics independently.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.nn.init import ones_init, zeros_init

# --------------------------------------------------------------------------
# BatchNorm


def _batch_moments(x, axes, valid):
    """f32 (mean, var) over ``axes``; rows with ``valid==False`` weightless.

    ``valid=None`` is the dense path and stays bit-identical to
    ``jnp.mean``/``jnp.var``.  With a ``(batch,)`` bool mask, masked rows
    contribute exactly zero to both moments (multiplication by a 0/1 f32
    weight is exact), so the statistics equal those of the surviving rows
    alone — the property elastic participation's oracle parity rests on.
    """
    x32 = x.astype(jnp.float32)
    if valid is None:
        return jnp.mean(x32, axis=axes), jnp.var(x32, axis=axes)
    w = valid.astype(jnp.float32).reshape((-1,) + (1,) * (x32.ndim - 1))
    spatial = math.prod(x32.shape[i] for i in axes if i != 0)
    cnt = jnp.maximum(jnp.sum(w), 1.0) * float(spatial)
    mean = jnp.sum(x32 * w, axis=axes) / cnt
    var = jnp.sum(w * jnp.square(x32 - mean), axis=axes) / cnt
    return mean, var


def batchnorm_init(key, dim, *, dtype=jnp.float32):
    params = {"scale": ones_init(key, (dim,), dtype),
              "bias": zeros_init(key, (dim,), dtype)}
    state = {"mean": jnp.zeros((dim,), jnp.float32),
             "var": jnp.ones((dim,), jnp.float32),
             "count": jnp.zeros((), jnp.float32)}
    return params, state


def batchnorm_apply(params, state, x, *, training, momentum=0.9, eps=1e-5,
                    use_running_stats=None, valid=None):
    """Returns (y, new_state).

    ``use_running_stats`` controls the inference statistics source:
      * True  -> RMSD (aggregated running mean/var)        [paper Table VI/VII]
      * False -> CMSD (current test-batch mean/var)        [paper Table VIII]
    Default at inference is RMSD; during training current-batch stats are
    always used for normalization while the running stats are updated.

    ``valid`` (optional ``(batch,)`` bool) drops rows from the batch
    statistics — the elastic-participation path where absent clients'
    rows ride along in the pooled batch but must not perturb the moments.
    ``valid=None`` is bit-identical to the dense computation.
    """
    axes = tuple(range(x.ndim - 1))
    if training:
        mean, var = _batch_moments(x, axes, valid)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
            "count": state["count"] + 1.0,
        }
    else:
        rmsd = True if use_running_stats is None else use_running_stats
        if rmsd:
            mean, var = state["mean"], state["var"]
        else:  # CMSD: statistics of the batch under test
            mean, var = _batch_moments(x, axes, valid)
        new_state = state
    x32 = x.astype(jnp.float32)
    y = (x32 - mean) * (1.0 / jnp.sqrt(var + eps))
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype), new_state


def batchnorm_act_apply(params, state, x, *, training, relu=True,
                        momentum=0.9, eps=1e-5, use_running_stats=None,
                        use_kernel=False, interpret=False, valid=None):
    """BatchNorm + optional ReLU with the elementwise tail fused.

    Same statistics semantics as :func:`batchnorm_apply` (training batch
    stats + running update; RMSD/CMSD at inference), but the per-channel
    normalize/scale/shift is folded into one f32 affine
    ``a = scale / sqrt(var + eps)``, ``b = bias - mean * a`` applied — with
    the ReLU — in a single sweep over ``x``.  The fold stays differentiable
    through the batch statistics, so autodiff's stat-gradients match the
    unfused form; the moments themselves are always computed in f32.
    ``use_kernel`` routes the sweep through the Pallas ``bn_act`` kernel
    (``interpret`` for CPU CI); off-kernel the fused jnp path is used.

    NOTE: the folded affine rounds differently from ``batchnorm_apply``'s
    subtract-then-scale at f32 — callers pinning bit-exact f32 parity
    (``policy=None`` in the split model) must keep the unfused path.
    """
    axes = tuple(range(x.ndim - 1))
    if training:
        mean, var = _batch_moments(x, axes, valid)
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
            "count": state["count"] + 1.0,
        }
    else:
        rmsd = True if use_running_stats is None else use_running_stats
        if rmsd:
            mean, var = state["mean"], state["var"]
        else:  # CMSD: statistics of the batch under test
            mean, var = _batch_moments(x, axes, valid)
        new_state = state
    a = params["scale"].astype(jnp.float32) / jnp.sqrt(var + eps)
    b = params["bias"].astype(jnp.float32) - mean * a
    if use_kernel:
        from repro.kernels.bn_act import ops as _ops
        y = _ops.bn_act(x, a, b, relu=relu, interpret=interpret)
    else:
        y32 = x.astype(jnp.float32) * a + b
        if relu:
            y32 = jnp.maximum(y32, 0.0)
        y = y32.astype(x.dtype)
    return y, new_state


# --------------------------------------------------------------------------
# LayerNorm / RMSNorm


def layernorm_init(key, dim, *, dtype=jnp.float32):
    return {"scale": ones_init(key, (dim,), dtype),
            "bias": zeros_init(key, (dim,), dtype)}


def layernorm_apply(params, x, *, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) / jnp.sqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm_init(key, dim, *, dtype=jnp.float32):
    return {"scale": ones_init(key, (dim,), dtype)}


def rmsnorm_apply(params, x, *, eps=1e-6, use_kernel=False, scale_offset=0.0):
    """RMSNorm. ``scale_offset=1.0`` gives the Gemma "(1+scale)" convention.

    ``use_kernel`` routes through the Pallas kernel (interpret on CPU).
    """
    if use_kernel:
        from repro.kernels.rmsnorm import ops as _ops
        return _ops.rmsnorm(x, params["scale"], eps=eps,
                            scale_offset=scale_offset)
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 / jnp.sqrt(ms + eps)
    y = y * (params["scale"].astype(jnp.float32) + scale_offset)
    return y.astype(x.dtype)
