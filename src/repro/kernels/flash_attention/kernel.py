"""Pallas-TPU flash attention (GQA, causal, sliding-window).

TPU-native adaptation: online-softmax over a 4-D grid
``(batch, q_head, q_block, kv_block)`` where the last dimension is the
sequential reduction axis ("arbitrary" dimension semantics). Running max /
denominator / accumulator live in VMEM scratch in fp32; block shapes are
MXU-aligned (multiples of 128 on the sequence dims, head_dim padded to 128
lanes by the caller). GQA loads each KV head once per q-head group via the
BlockSpec index map — no KV duplication in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -2.3819763e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, bq, bk, kv_len, num_kv_blocks):
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * bq
    k_start = j * bk

    # Block-level skip: fully-masked (causal / window / padding) blocks do no
    # compute. They still occupy a grid step, but the MXU work is gated off.
    relevant = k_start < kv_len
    if causal:
        relevant = jnp.logical_and(relevant, k_start <= q_start + bq - 1)
    if window is not None:
        relevant = jnp.logical_and(
            relevant, (q_start) - (k_start + bk - 1) < window)

    @pl.when(relevant)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        iq = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        ik = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = ik < kv_len                    # kv padding
        if causal:
            mask = jnp.logical_and(mask, iq - ik >= 0)
        if window is not None:
            mask = jnp.logical_and(mask, iq - ik < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        # rows with no valid kv (shouldn't happen for causal q<kv_len) get l=0
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, scale, causal=True, window=None,
                         kv_len=None, bq=128, bk=128, interpret=False):
    """q: (B, H, Sq, D); k/v: (B, K, Skv, D), Sq/Skv multiples of bq/bk.

    ``kv_len``: number of real (unpadded) kv positions (<= Skv).
    """
    B, H, Sq, D = q.shape
    K, Skv = k.shape[1], k.shape[2]
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    G = H // K
    kv_len = Skv if kv_len is None else kv_len
    nq, nk = Sq // bq, Skv // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, kv_len=kv_len, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="sfpl_flash_attention",
    )(q, k, v)
