"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D) with H % K == 0.

    Positions are assumed to be 0..S-1 (q and kv aligned, Sq == Skv).
    Returns (B, Sq, H, D) in q.dtype; softmax in fp32.
    """
    B, Sq, H, D = q.shape
    K = k.shape[2]
    G = H // K
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qf = q.reshape(B, Sq, K, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * scale
    Skv = k.shape[1]
    iq = jnp.arange(Sq)[:, None]
    ik = jnp.arange(Skv)[None, :]
    delta = iq - ik
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (delta >= 0)
    if window is not None:
        mask = mask & (delta < window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)
