"""jit'd public wrapper for the flash attention kernel.

Accepts model-layout tensors (B, S, H, D), pads sequence dims to block
multiples, dispatches to the Pallas kernel, and restores the layout.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    bq=128, bk=128, interpret=False):
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bq_ = min(bq, max(8, 1 << (Sq - 1).bit_length()))
    bk_ = min(bk, max(8, 1 << (Skv - 1).bit_length()))

    qt = _pad_to(jnp.transpose(q, (0, 2, 1, 3)), 2, bq_)
    kt = _pad_to(jnp.transpose(k, (0, 2, 1, 3)), 2, bk_)
    vt = _pad_to(jnp.transpose(v, (0, 2, 1, 3)), 2, bk_)

    out = flash_attention_bhsd(
        qt, kt, vt, scale=scale, causal=causal, window=window,
        kv_len=Skv, bq=bq_, bk=bk_, interpret=interpret)
    out = out[:, :, :Sq]
    return jnp.transpose(out, (0, 2, 1, 3))
