"""Pallas TPU kernels: flash_attention, rmsnorm, collector_permute.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle used by the test suite).
"""
