from repro.kernels.bn_act import ops, ref
