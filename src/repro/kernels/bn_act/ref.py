"""Pure-jnp oracle for the fused BN affine (+ ReLU) epilogue.

The BatchNorm statistics (batch or running, per the CMSD/RMSD policy) are
computed OUTSIDE this op in f32 and folded into one per-channel affine
``a = scale / sqrt(var + eps)``, ``b = bias - mean * a`` — the op is the
remaining elementwise tail that follows every conv in the split ResNet:
``y = relu?(x * a + b)``, computed in f32 and cast back to ``x.dtype``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bn_act_ref(x, a, b, *, relu=True):
    """x: (..., C) any float dtype; a, b: (C,) f32 folded BN affine.

    Returns ``relu(x * a + b)`` (or the bare affine with ``relu=False``)
    computed in f32, cast to ``x.dtype``."""
    y = (x.astype(jnp.float32) * a.astype(jnp.float32)
         + b.astype(jnp.float32))
    if relu:
        y = jax.nn.relu(y)
    return y.astype(x.dtype)
