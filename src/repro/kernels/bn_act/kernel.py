"""Pallas-TPU fused BatchNorm affine + ReLU epilogue.

Memory-bound elementwise tail of every conv in the split ResNet: one HBM
read of the conv output, one write of the activated tensor — vs the 3+
round trips of unfused normalize / scale-shift / relu. The per-channel
affine ``(a, b)`` is precomputed in f32 from the BN statistics (batch or
running, per the CMSD/RMSD policy), broadcast from one VMEM-resident
``(1, Cp)`` row; the multiply-add and the clamp happen in registers in
f32 and the result is cast to the compute dtype on the way out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

from repro.kernels._compat import tpu_compiler_params


def _bn_act_kernel(x_ref, a_ref, b_ref, o_ref, *, relu):
    x = x_ref[...].astype(jnp.float32)            # (br, Cp)
    y = x * a_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


def bn_act_2d(x, a, b, *, relu=True, block_rows=256, interpret=False):
    """x: (R, Cp) with R % block_rows == 0 and Cp a lane multiple;
    a, b: (Cp,) f32 folded BN affine. Returns ``relu?(x * a + b)`` in
    ``x.dtype``."""
    R, Cp = x.shape
    assert R % block_rows == 0, (R, block_rows)
    kernel = functools.partial(_bn_act_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, Cp), lambda i: (i, 0)),
            pl.BlockSpec((1, Cp), lambda i: (0, 0)),
            pl.BlockSpec((1, Cp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, Cp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Cp), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="sfpl_bn_act",
    )(x, a.reshape(1, Cp), b.reshape(1, Cp))
