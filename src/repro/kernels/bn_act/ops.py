"""jit'd wrapper for the fused BN affine + ReLU epilogue.

Reshapes ``(..., C)`` to rows, pads C to the 128-lane boundary and rows to
the block multiple, dispatches :func:`bn_act_2d`, and slices the result
back.  Differentiable via ``custom_vjp``: the backward pass is plain jnp
(already fused by XLA into one elementwise sweep) and recomputes nothing —
residuals are ``(x, a, y)`` and the ReLU mask is recovered from ``y > 0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bn_act.kernel import bn_act_2d


def _bn_act_fwd_2d(x2, a, b, *, relu, interpret):
    rows, c = x2.shape
    cp = max(128, -(-c // 128) * 128)
    if cp != c:
        x2p = jnp.pad(x2, ((0, 0), (0, cp - c)))
        ap = jnp.pad(a, (0, cp - c))
        bp = jnp.pad(b, (0, cp - c))
    else:
        x2p, ap, bp = x2, a, b
    br = min(256, max(8, 1 << (rows - 1).bit_length()))
    rp = -(-rows // br) * br
    if rp != rows:
        x2p = jnp.pad(x2p, ((0, rp - rows), (0, 0)))
    y = bn_act_2d(x2p, ap, bp, relu=relu, block_rows=br,
                  interpret=interpret)
    return y[:rows, :c]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _bn_act_ad(x2, a, b, relu, interpret):
    return _bn_act_fwd_2d(x2, a, b, relu=relu, interpret=interpret)


def _bn_act_ad_fwd(x2, a, b, relu, interpret):
    y = _bn_act_fwd_2d(x2, a, b, relu=relu, interpret=interpret)
    return y, (x2, a, y)


def _bn_act_ad_bwd(relu, interpret, res, g):
    x2, a, y = res
    g32 = g.astype(jnp.float32)
    if relu:
        g32 = jnp.where(y > 0, g32, 0.0)
    x32 = x2.astype(jnp.float32)
    dx = (g32 * a.astype(jnp.float32)).astype(x2.dtype)
    da = jnp.sum(g32 * x32, axis=0).astype(a.dtype)
    db = jnp.sum(g32, axis=0).astype(a.dtype)
    return dx, da, db


_bn_act_ad.defvjp(_bn_act_ad_fwd, _bn_act_ad_bwd)


@functools.partial(jax.jit, static_argnames=("relu", "interpret"))
def bn_act(x, a, b, *, relu=True, interpret=False):
    """Fused ``relu?(x * a + b)`` over the trailing channel axis.

    x: (..., C) any float dtype; a, b: (C,) f32 folded BN affine.
    Returns the activated tensor in ``x.dtype``; gradients flow to all
    three operands (f32 for ``a``/``b``)."""
    orig_shape = x.shape
    c = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    y = _bn_act_ad(x.reshape(rows, c), a, b, relu, interpret)
    return y.reshape(orig_shape)
