from repro.kernels.rmsnorm import ops, ref
