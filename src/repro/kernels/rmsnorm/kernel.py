"""Pallas-TPU fused RMSNorm.

Memory-bound op: one HBM read of x, one write of y (vs 3+ round trips when
unfused). Rows are tiled (block_rows, d) into VMEM; the mean-square reduction
and the scale multiply happen in registers in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps, scale_offset, d_real):
    x = x_ref[...].astype(jnp.float32)            # (br, dp)
    if d_real != x.shape[-1]:                     # feature-dim padding mask
        col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(col < d_real, x, 0.0)
    ms = jnp.sum(x * x, axis=-1, keepdims=True) / d_real
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (s_ref[...].astype(jnp.float32) + scale_offset)
                  ).astype(o_ref.dtype)


def rmsnorm_2d(x, scale, *, eps=1e-6, scale_offset=0.0, block_rows=256,
               d_real=None, interpret=False):
    """x: (R, Dp) with R % block_rows == 0; scale: (Dp,)."""
    R, Dp = x.shape
    assert R % block_rows == 0
    d_real = Dp if d_real is None else d_real
    kernel = functools.partial(_rmsnorm_kernel, eps=eps,
                               scale_offset=scale_offset, d_real=d_real)
    return pl.pallas_call(
        kernel,
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
            pl.BlockSpec((1, Dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, Dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Dp), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="sfpl_rmsnorm",
    )(x, scale.reshape(1, Dp))
