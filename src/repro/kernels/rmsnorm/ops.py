"""jit'd wrapper: reshapes (..., d) -> rows, pads rows/features, dispatches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_2d


@functools.partial(jax.jit, static_argnames=("eps", "scale_offset",
                                             "interpret"))
def rmsnorm(x, scale, *, eps=1e-6, scale_offset=0.0, interpret=False):
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    dp = max(128, -(-d // 128) * 128)
    if dp != d:
        x2 = jnp.pad(x2, ((0, 0), (0, dp - d)))
        scale_p = jnp.pad(scale, (0, dp - d))
    else:
        scale_p = scale
    br = min(256, max(8, 1 << (rows - 1).bit_length()))
    rp = -(-rows // br) * br
    if rp != rows:
        x2 = jnp.pad(x2, ((0, rp - rows), (0, 0)))

    y = rmsnorm_2d(x2, scale_p, eps=eps, scale_offset=scale_offset,
                   block_rows=br, d_real=d, interpret=interpret)
    return y[:rows, :d].reshape(orig_shape)
