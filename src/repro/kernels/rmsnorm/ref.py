"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps=1e-6, scale_offset=0.0):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 / jnp.sqrt(ms + eps)
    return (y * (scale.astype(jnp.float32) + scale_offset)).astype(x.dtype)
