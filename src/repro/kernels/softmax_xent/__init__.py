from repro.kernels.softmax_xent import ops, ref
