"""Pallas-TPU fused softmax cross-entropy (forward + backward sweeps).

One VMEM pass per direction over the (rows, classes) logits block:

* forward — per-row max / exp / sum in f32 registers, the label logit
  selected by an iota==label mask (no f32 logits materialized in HBM, no
  ``take_along_axis`` gather round-trip); emits per-row ``nll`` and the
  ``lse`` residual.
* backward — ``(softmax(x) - onehot(label)) * scale`` per row, with the
  softmax rebuilt from the saved ``lse`` (no second reduction).

Padded class columns are masked to -inf (forward) / zeroed (backward);
padded rows are neutralized by a zero per-row ``scale``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

from repro.kernels._compat import tpu_compiler_params


def _xent_fwd_kernel(x_ref, l_ref, loss_ref, lse_ref, *, v_real):
    x = x_ref[...].astype(jnp.float32)            # (br, Vp)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    if v_real != x.shape[-1]:                     # class-dim padding mask
        x = jnp.where(col < v_real, x, -jnp.inf)
    m = jnp.max(x, axis=-1, keepdims=True)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m), axis=-1, keepdims=True))
    ll = jnp.sum(jnp.where(col == l_ref[...], x, 0.0),
                 axis=-1, keepdims=True)
    loss_ref[...] = lse - ll
    lse_ref[...] = lse


def _xent_bwd_kernel(x_ref, l_ref, lse_ref, g_ref, dx_ref, *, v_real):
    x = x_ref[...].astype(jnp.float32)            # (br, Vp)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    p = jnp.exp(x - lse_ref[...])                 # softmax from saved lse
    onehot = (col == l_ref[...]).astype(jnp.float32)
    d = (p - onehot) * g_ref[...]                 # per-row scale (br, 1)
    if v_real != x.shape[-1]:
        d = jnp.where(col < v_real, d, 0.0)
    dx_ref[...] = d.astype(dx_ref.dtype)


def xent_fwd_2d(x, labels, *, v_real=None, block_rows=256, interpret=False):
    """x: (R, Vp), R % block_rows == 0; labels: (R, 1) int32 (pre-masked to
    valid class ids). Returns per-row ``(nll, lse)``, both (R, 1) f32."""
    R, Vp = x.shape
    assert R % block_rows == 0, (R, block_rows)
    kernel = functools.partial(_xent_fwd_kernel,
                               v_real=Vp if v_real is None else v_real)
    return pl.pallas_call(
        kernel,
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, Vp), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="sfpl_xent_fwd",
    )(x, labels)


def xent_bwd_2d(x, labels, lse, g, *, v_real=None, block_rows=256,
                interpret=False):
    """Backward sweep: x (R, Vp), labels (R, 1) int32, lse/g (R, 1) f32.
    Returns dlogits (R, Vp) in ``x.dtype``."""
    R, Vp = x.shape
    assert R % block_rows == 0, (R, block_rows)
    kernel = functools.partial(_xent_bwd_kernel,
                               v_real=Vp if v_real is None else v_real)
    return pl.pallas_call(
        kernel,
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, Vp), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, Vp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, Vp), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="sfpl_xent_bwd",
    )(x, labels, lse, g)
