"""Pure-jnp oracle for the fused softmax cross-entropy.

Numerically identical to ``models.common.softmax_cross_entropy`` (without
the optional z-loss term): f32 logsumexp minus the selected logit, mean
over non-ignored rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE_LABEL = -100


def softmax_xent_ref(logits, labels, *, ignore=IGNORE_LABEL):
    """logits: (..., V) any float dtype; labels: (...,) int. Mean f32 nll
    over non-ignored rows."""
    logits = logits.astype(jnp.float32)
    valid = labels != ignore
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = jnp.where(valid, lse - ll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(loss) / denom
