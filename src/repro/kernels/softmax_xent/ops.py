"""jit'd wrapper for the fused softmax cross-entropy.

Flattens ``(..., V)`` logits to rows, pads V to the 128-lane boundary and
rows to the block multiple, and dispatches the fwd/bwd Pallas sweeps via
``custom_vjp``.  The mean-over-valid-rows reduction stays outside the
custom rule, so autodiff delivers the per-row scale
``where(valid, g / denom, 0)`` that neutralizes both ignored and padded
rows in the backward kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.softmax_xent.kernel import xent_fwd_2d, xent_bwd_2d

IGNORE_LABEL = -100


def _pad_dims(rows, v):
    vp = max(128, -(-v // 128) * 128)
    br = min(256, max(8, 1 << (rows - 1).bit_length()))
    rp = -(-rows // br) * br
    return vp, br, rp


def _pad_rows(x2, lab2, vp, rp):
    rows, v = x2.shape
    if vp != v:
        x2 = jnp.pad(x2, ((0, 0), (0, vp - v)))
    if rp != rows:
        x2 = jnp.pad(x2, ((0, rp - rows), (0, 0)))
        lab2 = jnp.pad(lab2, ((0, rp - rows), (0, 0)))
    return x2, lab2


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _xent_rows(x2, lab2, interpret):
    """Per-row f32 nll, shape (rows, 1). lab2: (rows, 1) valid class ids."""
    loss, _ = _dispatch_fwd(x2, lab2, interpret)
    return loss


def _dispatch_fwd(x2, lab2, interpret):
    rows, v = x2.shape
    vp, br, rp = _pad_dims(rows, v)
    xp, lp = _pad_rows(x2, lab2, vp, rp)
    loss, lse = xent_fwd_2d(xp, lp, v_real=v, block_rows=br,
                            interpret=interpret)
    return loss[:rows], lse


def _xent_rows_fwd(x2, lab2, interpret):
    loss, lse = _dispatch_fwd(x2, lab2, interpret)
    return loss, (x2, lab2, lse)


def _xent_rows_bwd(interpret, res, g):
    x2, lab2, lse = res                           # lse is padded (rp, 1)
    rows, v = x2.shape
    vp, br, rp = _pad_dims(rows, v)
    xp, lp = _pad_rows(x2, lab2, vp, rp)
    gp = g.astype(jnp.float32)
    if rp != rows:                                # padded rows: zero scale
        gp = jnp.pad(gp, ((0, rp - rows), (0, 0)))
    dx = xent_bwd_2d(xp, lp, lse, gp, v_real=v, block_rows=br,
                     interpret=interpret)[:rows, :v]
    return dx, np.zeros(lab2.shape, dtype=jax.dtypes.float0)


_xent_rows.defvjp(_xent_rows_fwd, _xent_rows_bwd)


@functools.partial(jax.jit, static_argnames=("ignore", "interpret"))
def softmax_xent(logits, labels, *, ignore=IGNORE_LABEL, interpret=False):
    """Fused drop-in for ``softmax_cross_entropy`` (no z-loss): logits
    (..., V) any float dtype, labels (...,) int.  Mean f32 nll over
    non-ignored rows; gradients flow to ``logits`` in its dtype."""
    v = logits.shape[-1]
    rows = 1
    for s in logits.shape[:-1]:
        rows *= s
    x2 = logits.reshape(rows, v)
    lab = labels.reshape(rows)
    valid = lab != ignore
    safe = jnp.where(valid, lab, 0).astype(jnp.int32)
    nll = _xent_rows(x2, safe.reshape(rows, 1), interpret)[:, 0]
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom
