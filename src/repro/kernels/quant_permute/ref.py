"""Pure-jnp oracles for the fused quantize-permute collector gathers.

Quantization semantics live in ``core.wire`` (per-row symmetric amax
scaling); these oracles compose them with the plain gather refs so the
Pallas kernels have an exact bit-for-bit comparison target — and so the
collector's non-kernel path shares one implementation with the tests.
"""
from __future__ import annotations

from repro.core import wire as W


def quant_bucket_permute_ref(x, idx, wire_dtype):
    """x: (R, d) float rows; idx: (S, cap) or flat (S*cap,). Returns
    ``(q, scales)`` with ``q[k] = quantize(x[idx.flat[k]])`` in the wire
    dtype and f32 scales (S*cap,) in the same bucketed order."""
    return W.quantize_rows(x[idx.reshape(-1)], wire_dtype)


def dequant_unbucket_permute_ref(q, scales, idx, out_dtype):
    """q: (R, d) flat received wire rows with (R,) f32 scales; idx: (B,).
    Returns the dequantized shuffled slab ``q[idx] * scales[idx]`` in
    ``out_dtype``."""
    return W.dequantize_rows(q[idx], scales[idx], out_dtype)
