"""Pallas-TPU kernels fusing wire quantization into the collector gathers.

The quantized exchange needs two extra element-wise passes over the
smashed rows — scale into the wire grid before the ``all_to_all``, scale
back out after — and both land exactly where the route-plan gathers
already stream every row HBM->VMEM->HBM. Fusing them into the gather
kernels makes the wire conversion free of extra memory traffic:

  * ``quant_bucket_permute_2d`` — the SEND side: gather local rows into
    send-bucket layout (``bucket_permute_2d``'s two-level prefetched
    index map) and, in the same pass over each row tile, reduce the row
    amax, emit the int8/fp8 row, and write its f32 scale;
  * ``dequant_unbucket_permute_2d`` — the RECEIVE mirror: gather the
    flat received block into output order while multiplying each row by
    its (prefetched-index-selected) scale back into the compute dtype.

Both kernels take ONE ROW per grid cell (block ``(1, Dp)``): the amax
reduction needs the whole row in VMEM, so the feature dim is not tiled.
The collector's smashed rows are a few hundred lanes after padding —
far under VMEM pressure; reshape upstream if a future cut layer breaks
that assumption.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _quant_kernel(qmax, round_to_int, idx_ref, x_ref, q_ref, s_ref):
    del idx_ref  # consumed by the index map, not the body
    row = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(row))
    inv = jnp.where(amax > 0, qmax / jnp.where(amax > 0, amax, 1.0), 0.0)
    y = row * inv
    if round_to_int:
        y = jnp.round(y)
    q_ref[...] = y.astype(q_ref.dtype)
    # reciprocal multiply, matching core.wire.quantize_rows bit-for-bit
    s_ref[...] = jnp.full(s_ref.shape, amax * jnp.float32(1.0 / qmax),
                          jnp.float32)


def quant_bucket_permute_2d(x, idx, wire_dtype, qmax, *, interpret=False):
    """Fused quantize + send-side bucket gather.

    x: (R, D) local float rows; idx: (S, cap) int32 two-level
    (destination shard, bucket slot) -> source row map. Returns
    ``(q, scales)``: q (S*cap, D) in ``wire_dtype`` with
    ``q[s*cap + r] = quantize(x[idx[s, r]])`` and f32 scales
    (S*cap, 1), one per BUCKETED row (scales ship in send layout —
    they cross the wire with their rows). Zero-padded feature columns
    cannot perturb the amax."""
    R, D = x.shape
    S, cap = idx.shape
    grid = (S, cap)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, D), lambda s, r, idx: (idx[s, r], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda s, r, idx: (s * cap + r, 0)),
            pl.BlockSpec((1, 1), lambda s, r, idx: (s * cap + r, 0)),
        ],
    )
    round_to_int = jnp.issubdtype(jnp.dtype(wire_dtype), jnp.integer)
    return pl.pallas_call(
        functools.partial(_quant_kernel, float(qmax), round_to_int),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((S * cap, D), wire_dtype),
                   jax.ShapeDtypeStruct((S * cap, 1), jnp.float32)],
        interpret=interpret,
        name="sfpl_quant_bucket_permute",
    )(idx.astype(jnp.int32), x)


def _dequant_kernel(idx_ref, s_ref, x_ref, o_ref):
    del idx_ref
    o_ref[...] = (x_ref[...].astype(jnp.float32)
                  * s_ref[0, 0]).astype(o_ref.dtype)


def dequant_unbucket_permute_2d(q, scales, idx, out_dtype, *,
                                interpret=False):
    """Fused receive-side unbucket gather + dequantize.

    q: (R, D) flat received wire-dtype block (plus the zero pad row on
    slack-buffered plans — its packed scale is 0.0, so it dequantizes to
    exact zeros); scales: (R, 1) f32 per-row scales in the same flat
    order; idx: (B,) int32 output row -> flat slot. Returns (B, D) in
    ``out_dtype`` with ``out[i] = q[idx[i]] * scales[idx[i]]`` — the
    shuffled compute-dtype slab in one pass, scale selection riding the
    same prefetched index map as the row gather."""
    R, D = q.shape
    (B,) = idx.shape
    grid = (B,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, idx: (idx[i], 0)),
            pl.BlockSpec((1, D), lambda i, idx: (idx[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), out_dtype),
        interpret=interpret,
        name="sfpl_dequant_unbucket_permute",
    )(idx.astype(jnp.int32), scales, q)
