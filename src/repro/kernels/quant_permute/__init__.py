from repro.kernels.quant_permute import ops, ref
