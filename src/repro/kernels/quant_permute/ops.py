"""jit'd wrappers for the fused quantize-permute collector kernels.

The wrappers speak the exchange's FLATTENED layout: the collector packs
quantized rows and bitcast scale lanes into one 2-D wire payload for the
``all_to_all``, so both ops take/return ``(rows, features)`` arrays
(``quant_bucket_permute`` flattens nd inputs itself) and the caller
reshapes the dequantized slab back to the smashed feature shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.wire import QMAX, WIRE_DTYPES
from repro.kernels.collector_permute.ops import _flatten_features
from repro.kernels.quant_permute.kernel import (
    dequant_unbucket_permute_2d, quant_bucket_permute_2d)


@functools.partial(jax.jit, static_argnames=("wire_dtype", "interpret"))
def quant_bucket_permute(x, idx, *, wire_dtype, interpret=False):
    """Fused send-side quantize + bucket gather: x (R, ...) local float
    rows, idx (S, cap) two-level (destination shard, slot) -> source row
    map. Returns ``(q, scales)``: q (S*cap, d) in the wire dtype with
    the feature dims flattened, f32 scales (S*cap,), both in send-bucket
    order — ``q[s*cap + r], scales[s*cap + r]`` quantize ``x[idx[s, r]]``."""
    x2, d, _, _, _ = _flatten_features(x)
    q, s = quant_bucket_permute_2d(
        x2, idx, WIRE_DTYPES[wire_dtype], QMAX[wire_dtype],
        interpret=interpret)
    return q[:, :d], s[:, 0]


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def dequant_unbucket_permute(q, scales, idx, *, out_dtype,
                             interpret=False):
    """Fused receive-side unbucket gather + dequantize: q (R, d) flat
    received wire rows, scales (R,) f32, idx (B,) output row -> flat
    slot. Returns the (B, d) dequantized shuffled slab in ``out_dtype``
    (caller reshapes to the smashed feature shape)."""
    q2, d, _, _, _ = _flatten_features(q)
    out = dequant_unbucket_permute_2d(
        q2, scales.reshape(-1, 1), idx, jnp.dtype(out_dtype),
        interpret=interpret)
    return out[:, :d]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def quant_dequant_roundtrip_ad(x, send_idx, recv_idx, wire_dtype,
                               interpret=False):
    """Differentiable fused round trip: ``quant_bucket_permute`` then
    ``dequant_unbucket_permute`` (what one quantized exchange applies to
    the rows, minus the collective). The VJP is STRAIGHT-THROUGH:
    dequantize-of-quantize is treated as the identity, so gradients route
    purely by the composed gather — exactly the convention
    ``plan_shuffle``'s backward exchange implements (the backward plan
    moves cotangents of the DEQUANTIZED values; the quantization error is
    not differentiated). Exists for direct AD through the kernel pair
    (tests, ad-hoc pipelines); the round engine routes gradients by the
    precomputed inverse plan."""
    q, s = quant_bucket_permute(x, send_idx, wire_dtype=wire_dtype,
                                interpret=interpret)
    out = dequant_unbucket_permute(q, s, recv_idx, out_dtype=x.dtype,
                                   interpret=interpret)
    return out.reshape((recv_idx.shape[0],) + x.shape[1:])


def _roundtrip_fwd(x, send_idx, recv_idx, wire_dtype, interpret):
    out = quant_dequant_roundtrip_ad(x, send_idx, recv_idx, wire_dtype,
                                     interpret)
    return out, (send_idx, recv_idx, x.shape)


def _roundtrip_bwd(wire_dtype, interpret, res, g):
    send_idx, recv_idx, shape = res
    src = send_idx.reshape(-1)[recv_idx]     # out[i] <- x[src[i]]
    gx = jnp.zeros(shape, g.dtype)
    return gx.at[src].add(g), None, None


quant_dequant_roundtrip_ad.defvjp(_roundtrip_fwd, _roundtrip_bwd)
