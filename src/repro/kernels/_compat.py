"""Version compatibility for the Pallas-TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
moved ``jax.experimental.shard_map.shard_map`` to ``jax.shard_map``) across
0.4.x -> 0.5/0.6. The repo targets whichever is installed; all kernels and
collective modules route through these helpers instead of touching the
moving names directly.
"""
from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

_TPU_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` on new jax, ``TPUCompilerParams``
    on <= 0.4.x."""
    return _TPU_COMPILER_PARAMS(**kwargs)


def auto_use_kernel(flag):
    """Resolve the repo-wide ``use_kernel=None`` convention: None means
    "auto" — Pallas kernels on when the default backend is TPU, the
    reference path everywhere else."""
    if flag is None:
        return jax.default_backend() == "tpu"
    return bool(flag)


def get_shard_map():
    """``jax.shard_map`` when present (jax >= 0.6), else the experimental
    spelling that 0.4.x ships."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map
    return shard_map
