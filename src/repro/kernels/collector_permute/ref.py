"""Pure-jnp oracle for the SFPL collector permutation (batched row gather)."""
from __future__ import annotations


def permute_ref(x, perm):
    """x: (R, d) pooled smashed data; perm: (R,) int32. out[i] = x[perm[i]]."""
    return x[perm]
