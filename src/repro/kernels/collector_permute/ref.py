"""Pure-jnp oracles for the SFPL collector gathers (batched row gathers)."""
from __future__ import annotations


def permute_ref(x, perm):
    """x: (R, d) pooled smashed data; perm: (R,) int32. out[i] = x[perm[i]]."""
    return x[perm]


def bucket_permute_ref(x, idx):
    """x: (R, d); idx: (S, cap). out[s*cap + r] = x[idx[s, r]]."""
    return x[idx.reshape(-1)]


def unbucket_permute_ref(x, idx):
    """x: (R, d) flat received block; idx: (B,). out[i] = x[idx[i]]."""
    return x[idx]
