"""Pallas-TPU kernel for the SFPL global-collector shuffle.

The collector's shuffle/de-shuffle is a batched row gather over the pooled
smashed-data tensor: ``out[i] = x[perm[i]]``. On TPU this is a one-pass
HBM->VMEM->HBM copy when the permutation is prefetched to SMEM and used in
the *BlockSpec index map* — each grid cell DMAs exactly its source tile, so
no intermediate materialization or scatter is needed
(PrefetchScalarGridSpec pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _permute_kernel(perm_ref, x_ref, o_ref):
    del perm_ref  # consumed by the index map, not the body
    o_ref[...] = x_ref[...]


def collector_permute_2d(x, perm, *, block_d=512, interpret=False):
    """x: (R, D) pooled smashed data (row-major, one row per sample);
    perm: (R,) int32 destination->source map. Returns x[perm]."""
    R, D = x.shape
    assert D % block_d == 0, (D, block_d)
    grid = (R, D // block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda i, j, perm: (perm[i], j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j, perm: (i, j)),
    )
    return pl.pallas_call(
        _permute_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
        name="sfpl_collector_permute",
    )(perm.astype(jnp.int32), x)
