"""Pallas-TPU kernels for the SFPL global-collector shuffle.

The collector's data movement is batched row gathers over the pooled
smashed-data tensor. On TPU each is a one-pass HBM->VMEM->HBM copy when
the gather indices are prefetched to SMEM and used in the *BlockSpec index
map* — every grid cell DMAs exactly its source tile, so no intermediate
materialization or scatter is needed (PrefetchScalarGridSpec pattern).

Three gathers share the pattern:

  * ``collector_permute_2d`` — the flat pool shuffle ``out[i] = x[perm[i]]``
    (single-device collector, and the legacy local permute);
  * ``bucket_permute_2d``    — the route-plan SEND side: gather local rows
    directly into send-bucket layout, ``out[s*cap + r] = x[idx[s, r]]``,
    via a TWO-LEVEL (destination bucket, slot) grid whose prefetched index
    map resolves both levels;
  * ``unbucket_permute_2d``  — its receive-side mirror: gather the flat
    received bucket block into local output order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _permute_kernel(perm_ref, x_ref, o_ref):
    del perm_ref  # consumed by the index map, not the body
    o_ref[...] = x_ref[...]


def collector_permute_2d(x, perm, *, block_d=512, interpret=False):
    """x: (R, D) pooled smashed data (row-major, one row per sample);
    perm: (R,) int32 destination->source map. Returns x[perm]."""
    R, D = x.shape
    assert D % block_d == 0, (D, block_d)
    grid = (R, D // block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda i, j, perm: (perm[i], j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j, perm: (i, j)),
    )
    return pl.pallas_call(
        _permute_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
        name="sfpl_collector_permute",
    )(perm.astype(jnp.int32), x)


def bucket_permute_2d(x, idx, *, block_d=512, interpret=False):
    """Route-plan send-side gather into bucket layout.

    x: (R, D) local rows; idx: (S, cap) int32 — the plan's two-level
    (destination shard, bucket slot) -> source row map (``RoutePlan.
    send_idx`` reshaped). Returns (S*cap, D) with
    ``out[s*cap + r] = x[idx[s, r]]`` — the exact send buffer the
    ``all_to_all`` ships, written in one pass: the grid iterates buckets
    then slots, and the prefetched index map resolves both levels to the
    source tile, so rows stream HBM->HBM without an intermediate
    sorted/stacked copy."""
    R, D = x.shape
    S, cap = idx.shape
    assert D % block_d == 0, (D, block_d)
    grid = (S, cap, D // block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d),
                         lambda s, r, j, idx: (idx[s, r], j)),
        ],
        out_specs=pl.BlockSpec((1, block_d),
                               lambda s, r, j, idx: (s * cap + r, j)),
    )
    return pl.pallas_call(
        _permute_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S * cap, D), x.dtype),
        interpret=interpret,
        name="sfpl_bucket_permute",
    )(idx.astype(jnp.int32), x)


def unbucket_permute_2d(x, idx, *, block_d=512, interpret=False):
    """Route-plan receive-side mirror of ``bucket_permute_2d``.

    x: (R, D) flat received bucket block (``S*cap`` rows, plus the zero
    pad row on slack-buffered plans); idx: (B,) int32 — the plan's
    ``recv_idx``: local output row -> flat (source shard, slot). Returns
    (B, D) with ``out[i] = x[idx[i]]`` — the shuffled output slab, again
    one DMA per tile with no scatter."""
    R, D = x.shape
    (B,) = idx.shape
    assert D % block_d == 0, (D, block_d)
    grid = (B, D // block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_d), lambda i, j, idx: (idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i, j, idx: (i, j)),
    )
    return pl.pallas_call(
        _permute_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        interpret=interpret,
        name="sfpl_unbucket_permute",
    )(idx.astype(jnp.int32), x)
