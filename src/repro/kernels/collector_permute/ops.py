"""jit'd wrapper for the collector permutation kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.collector_permute.kernel import collector_permute_2d


@functools.partial(jax.jit, static_argnames=("interpret",))
def collector_permute(x, perm, *, interpret=False):
    """x: (R, ...) smashed-data stack; perm: (R,). Returns x[perm]."""
    orig_shape = x.shape
    R = orig_shape[0]
    d = 1
    for s in orig_shape[1:]:
        d *= s
    x2 = x.reshape(R, d)
    dp = max(128, -(-d // 128) * 128)
    if dp != d:
        x2 = jnp.pad(x2, ((0, 0), (0, dp - d)))
    block_d = dp if dp <= 512 else 512 if dp % 512 == 0 else 128
    y = collector_permute_2d(x2, perm, block_d=block_d, interpret=interpret)
    return y[:, :d].reshape(orig_shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def collector_permute_ad(x, perm, interpret=False):
    """Differentiable ``collector_permute``: the VJP of a row gather is the
    gather by the inverse permutation — i.e. Algorithm 1's gradient
    de-shuffle, so backprop through the kernelized collector routes
    activation gradients back to their source rows with the same one-pass
    Pallas kernel."""
    return collector_permute(x, perm, interpret=interpret)


def _permute_fwd(x, perm, interpret):
    return collector_permute(x, perm, interpret=interpret), perm


def _permute_bwd(interpret, perm, g):
    return collector_permute(g, jnp.argsort(perm), interpret=interpret), None


collector_permute_ad.defvjp(_permute_fwd, _permute_bwd)
