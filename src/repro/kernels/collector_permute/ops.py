"""jit'd wrappers for the collector permutation / bucket gather kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.collector_permute.kernel import (
    bucket_permute_2d, collector_permute_2d, unbucket_permute_2d)


def _flatten_features(x):
    """(R, ...) -> (R, Dp) with the feature dims flattened and padded to a
    TPU-friendly lane multiple; returns (x2, d, dp, block_d, feat_shape)."""
    orig_shape = x.shape
    R = orig_shape[0]
    d = 1
    for s in orig_shape[1:]:
        d *= s
    x2 = x.reshape(R, d)
    dp = max(128, -(-d // 128) * 128)
    if dp != d:
        x2 = jnp.pad(x2, ((0, 0), (0, dp - d)))
    block_d = dp if dp <= 512 else 512 if dp % 512 == 0 else 128
    return x2, d, dp, block_d, orig_shape[1:]


@functools.partial(jax.jit, static_argnames=("interpret",))
def collector_permute(x, perm, *, interpret=False):
    """x: (R, ...) smashed-data stack; perm: (R,). Returns x[perm]."""
    x2, d, _, block_d, feat = _flatten_features(x)
    y = collector_permute_2d(x2, perm, block_d=block_d, interpret=interpret)
    return y[:, :d].reshape((x.shape[0],) + feat)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bucket_permute(x, idx, *, interpret=False):
    """Route-plan send gather: x: (R, ...) local rows, idx: (S, cap) the
    two-level (destination bucket, slot) -> source row map. Returns the
    (S*cap, ...) send buffer ``out[s*cap + r] = x[idx[s, r]]``.

    S is the exchange's bucket shard count, NOT necessarily the full mesh:
    under sub-mesh streaming each flush group's exchange is confined to
    its owning shard slice, so ``(S, cap)`` is the sub-mesh-local
    ``(slice_size, b // slice_size)`` and varies per group. The kernel is
    shape-generic — the two-level index map carries the bucket count in
    ``idx.shape`` — so no per-group recompilation beyond jit's usual
    shape specialization."""
    x2, d, _, block_d, feat = _flatten_features(x)
    y = bucket_permute_2d(x2, idx, block_d=block_d, interpret=interpret)
    return y[:, :d].reshape((idx.shape[0] * idx.shape[1],) + feat)


@functools.partial(jax.jit, static_argnames=("interpret",))
def unbucket_permute(x, idx, *, interpret=False):
    """Route-plan receive gather (the ``bucket_permute`` mirror): x:
    (R, ...) flat received block, idx: (B,) output row -> flat slot.
    Returns the (B, ...) shuffled slab ``out[i] = x[idx[i]]``. Under
    sub-mesh streaming R is the sub-mesh-local ``slice_size * cap``
    (== the slab), not the full mesh's receive width."""
    x2, d, _, block_d, feat = _flatten_features(x)
    y = unbucket_permute_2d(x2, idx, block_d=block_d, interpret=interpret)
    return y[:, :d].reshape((idx.shape[0],) + feat)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def collector_permute_ad(x, perm, interpret=False):
    """Differentiable ``collector_permute``: the VJP of a row gather is the
    gather by the inverse permutation — i.e. Algorithm 1's gradient
    de-shuffle, so backprop through the kernelized collector routes
    activation gradients back to their source rows with the same one-pass
    Pallas kernel."""
    return collector_permute(x, perm, interpret=interpret)


def _permute_fwd(x, perm, interpret):
    return collector_permute(x, perm, interpret=interpret), perm


def _permute_bwd(interpret, perm, g):
    return collector_permute(g, jnp.argsort(perm), interpret=interpret), None


collector_permute_ad.defvjp(_permute_fwd, _permute_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def bucket_permute_ad(x, idx, interpret=False):
    """Differentiable ``bucket_permute``. The backward pass is a plain jnp
    scatter-add (``gx[idx[s, r]] += g[s*cap + r]``) rather than the
    kernel: the VJP of a gather is only itself a gather when ``idx`` is a
    permutation, and the index map isn't statically known to be one.
    Route-plan production gradients never come through here — they ride
    the precomputed inverse plan — so this exists for direct AD through
    the kernelized gathers (tests, ad-hoc pipelines)."""
    return bucket_permute(x, idx, interpret=interpret)


def _bucket_fwd(x, idx, interpret):
    return bucket_permute(x, idx, interpret=interpret), (idx, x.shape)


def _bucket_bwd(interpret, res, g):
    idx, shape = res
    gx = jnp.zeros(shape, g.dtype)
    return gx.at[idx.reshape(-1)].add(g), None


bucket_permute_ad.defvjp(_bucket_fwd, _bucket_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def unbucket_permute_ad(x, idx, interpret=False):
    """Differentiable ``unbucket_permute`` (same contract and caveats as
    ``bucket_permute_ad``: jnp scatter-add backward)."""
    return unbucket_permute(x, idx, interpret=interpret)


def _unbucket_fwd(x, idx, interpret):
    return unbucket_permute(x, idx, interpret=interpret), (idx, x.shape)


def _unbucket_bwd(interpret, res, g):
    idx, shape = res
    gx = jnp.zeros(shape, g.dtype)
    return gx.at[idx].add(g), None


unbucket_permute_ad.defvjp(_unbucket_fwd, _unbucket_bwd)
