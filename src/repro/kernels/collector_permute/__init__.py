from repro.kernels.collector_permute import ops, ref
