"""jit'd wrapper: pads batch/time to block multiples, dispatches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.slstm_scan.kernel import slstm_scan_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_scan(pre_i, pre_f, pre_z, pre_o, R, *, interpret=False):
    """pre_*: (B, S, H, Dh); R: (4, H, Dh, Dh). Returns h (B, S, H, Dh).

    Padded time steps use -inf forget preactivation... note: padding with
    zeros is safe because padded steps come AFTER all real steps (state for
    real outputs is unaffected) and their outputs are sliced away.
    """
    B, S, H, Dh = pre_i.shape
    HD = H * Dh
    bb = min(8, B)
    while B % bb:
        bb -= 1
    tc = min(64, S)
    Sp = -(-S // tc) * tc
    flat = lambda p: jnp.pad(p.reshape(B, S, HD).astype(jnp.float32),
                             ((0, 0), (0, Sp - S), (0, 0)))
    out = slstm_scan_pallas(flat(pre_i), flat(pre_f), flat(pre_z),
                            flat(pre_o), R.astype(jnp.float32),
                            block_b=bb, time_chunk=tc, interpret=interpret)
    return out[:, :S].reshape(B, S, H, Dh)
