from repro.kernels.slstm_scan import ops, ref
