"""Pure-jnp oracle for the fused sLSTM scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_scan_ref(pre_i, pre_f, pre_z, pre_o, R, state0):
    """Stabilized sLSTM recurrence (xLSTM eqs.), block-diagonal per head.

    pre_*: (B, S, H, Dh) fp32 input-side gate preactivations.
    R: (4, H, Dh, Dh) recurrent matrices in gate order (i, f, z, o).
    state0: (c, n, m, h) each (B, H, Dh) fp32.
    Returns h_seq (B, S, H, Dh) and the final state tuple.
    """
    def step(carry, xs):
        c, n, m, h = carry
        xi, xf, xz, xo = xs
        rec = jnp.einsum("bhd,ghde->gbhe", h, R)
        i_pre = xi + rec[0]
        f_pre = xf + rec[1]
        z = jnp.tanh(xz + rec[2])
        o = jax.nn.sigmoid(xo + rec[3])
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = jnp.maximum(f_s * n + i_s, 1e-6)
        h_new = o * (c_new / n_new)
        return (c_new, n_new, m_new, h_new), h_new

    xs = tuple(p.swapaxes(0, 1) for p in (pre_i, pre_f, pre_z, pre_o))
    final, hs = jax.lax.scan(step, state0, xs)
    return hs.swapaxes(0, 1), final
