"""Pallas-TPU fused sLSTM scan.

Motivation (EXPERIMENTS.md §Perf iter 14): the sLSTM hidden-to-hidden
recurrence is sequential over time; any sharded-pjit formulation pays a
per-timestep collective or gather. This kernel keeps the (c, n, m, h) state
resident in VMEM scratch and runs the time loop ON-CHIP:

  grid = (B_blocks, S_chunks)  — S_chunks is the sequential dimension; the
  state scratch carries across chunks. Each grid cell loads a
  (bb, ts, H*Dh) tile of the four gate preactivations, loops ``ts`` steps
  with the per-head block-diagonal recurrent matmuls (Dh x Dh — MXU-aligned
  for Dh in {128..512}), and writes the h tile.

Head-local layout: R matrices are replicated per device (heads < TP degree),
so the kernel involves no cross-chip traffic at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _slstm_kernel(xi_ref, xf_ref, xz_ref, xo_ref, r_ref, o_ref,
                  c_ref, n_ref, m_ref, h_ref, *, ts, H, Dh):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.full_like(n_ref, 1e-6)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        h_ref[...] = jnp.zeros_like(h_ref)

    R = r_ref[...].astype(jnp.float32)            # (4, H, Dh, Dh)

    def step(t, _):
        h = h_ref[...].reshape(-1, H, Dh)         # (bb, H, Dh)
        rec = jnp.einsum("bhd,ghde->gbhe", h, R,
                         preferred_element_type=jnp.float32)
        rec = rec.reshape(4, -1, H * Dh)
        xi = xi_ref[:, t].astype(jnp.float32)     # (bb, HD)
        xf = xf_ref[:, t].astype(jnp.float32)
        xz = xz_ref[:, t].astype(jnp.float32)
        xo = xo_ref[:, t].astype(jnp.float32)
        i_pre = xi + rec[0]
        f_pre = xf + rec[1]
        z = jnp.tanh(xz + rec[2])
        o = jax.nn.sigmoid(xo + rec[3])
        logf = jax.nn.log_sigmoid(f_pre)
        m = m_ref[...]
        m_new = jnp.maximum(logf + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c_ref[...] + i_s * z
        n_new = jnp.maximum(f_s * n_ref[...] + i_s, 1e-6)
        h_new = o * (c_new / n_new)
        c_ref[...] = c_new
        n_ref[...] = n_new
        m_ref[...] = m_new
        h_ref[...] = h_new
        o_ref[:, t] = h_new.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, ts, step, 0)


def slstm_scan_pallas(pre_i, pre_f, pre_z, pre_o, R, *, block_b=8,
                      time_chunk=64, interpret=False):
    """pre_*: (B, S, HD) fp32; R: (4, H, Dh, Dh). Returns h (B, S, HD).

    B % block_b == 0 and S % time_chunk == 0 (the ops wrapper pads).
    """
    B, S, HD = pre_i.shape
    _, H, Dh, _ = R.shape
    assert H * Dh == HD
    assert B % block_b == 0 and S % time_chunk == 0
    grid = (B // block_b, S // time_chunk)

    kernel = functools.partial(_slstm_kernel, ts=time_chunk, H=H, Dh=Dh)
    x_spec = pl.BlockSpec((block_b, time_chunk, HD),
                          lambda b, j: (b, j, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, x_spec,
                  pl.BlockSpec((4, H, Dh, Dh), lambda b, j: (0, 0, 0, 0))],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, HD), pre_i.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, HD), jnp.float32)
                        for _ in range(4)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="sfpl_slstm_scan",
    )(pre_i, pre_f, pre_z, pre_o, R)
