"""Continuous-batching serving scheduler (vLLM-style slot management).

A fixed pool of B decode slots runs one jitted decode step per tick with a
*per-slot* position vector (repro.nn.attention supports vector ``cur_pos``).
Requests join whenever a slot frees up — prompt tokens are teacher-forced
through the same decode path (per-slot, so other slots keep generating
while one slot is still prefilling), and completed requests leave without
stalling the batch. Greedy or temperature sampling per slot.

This is the serving-side integration of the split-learning deployment: in
the SFPL setting the client-side portion runs on-device and ships smashed
activations; here the server-side decode pool is the natural continuation
(DESIGN.md §5 notes the cut; serving uses the full model for simplicity).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.launch.steps import make_decode_step


@dataclasses.dataclass
class Request:
    prompt: list                   # token ids
    max_new_tokens: int = 16
    temperature: float = 0.0
    # filled by the scheduler:
    output: Optional[list] = None
    slot: Optional[int] = None


class ContinuousBatcher:
    """Slot-pool scheduler over a transformer-family decode step."""

    def __init__(self, spec, cfg, params, *, num_slots=4, max_len=128,
                 seed=0):
        assert spec.family == "transformer", "scheduler targets LM decode"
        self.spec, self.cfg, self.params = spec, cfg, params
        self.B = num_slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(make_decode_step(spec, cfg))
        self.state = spec.model.init_decode_state(cfg, num_slots, max_len,
                                                  dtype=jnp.float32)
        # per-slot bookkeeping (host side)
        self.pos = [0] * num_slots          # next position to write
        self.active: List[Optional[Request]] = [None] * num_slots
        self.pending: List[Request] = []
        self.done: List[Request] = []
        self._next_tok = [0] * num_slots    # token to feed this tick

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.output = []
        self.pending.append(req)

    def _admit(self):
        for s in range(self.B):
            if self.active[s] is None and self.pending:
                req = self.pending.pop(0)
                req.slot = s
                self.active[s] = req
                self.pos[s] = 0
                self._next_tok[s] = req.prompt[0]
                # recycle: mark every cached position of this slot invalid
                self.state = self._invalidate_slot(self.state, s)

    def _invalidate_slot(self, state, s):
        def inv(path, a):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name == "pos":
                return a.at[:, s].set(-1)
            return a
        return jax.tree_util.tree_map_with_path(inv, state)

    # ------------------------------------------------------------------
    def step(self):
        """One decode tick for all slots. Returns number of active slots."""
        self._admit()
        if not any(self.active):
            return 0
        toks = jnp.asarray([[self._next_tok[s]] for s in range(self.B)],
                           jnp.int32)
        cur = jnp.asarray(self.pos, jnp.int32)
        logits, self.state = self._decode(self.params, self.state, toks,
                                          cur)
        self.key, ks = jax.random.split(self.key)
        greedy = jnp.argmax(logits[:, -1], axis=-1)
        sampled = jax.random.categorical(ks, logits[:, -1] / 0.8)

        for s in range(self.B):
            req = self.active[s]
            if req is None:
                continue
            self.pos[s] += 1
            if self.pos[s] < len(req.prompt):
                # still prefilling: feed the next prompt token
                self._next_tok[s] = req.prompt[self.pos[s]]
                continue
            tok = int(sampled[s] if req.temperature > 0 else greedy[s])
            req.output.append(tok)
            self._next_tok[s] = tok
            if (len(req.output) >= req.max_new_tokens
                    or self.pos[s] >= self.max_len - 1):
                self.done.append(req)
                self.active[s] = None

    def run(self, max_ticks=10_000):
        ticks = 0
        while (self.pending or any(self.active)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done, ticks
