from repro.metrics.classification import (
    precision_at_1, recall_macro, f1_macro, accuracy, confusion_matrix,
    classification_report)
