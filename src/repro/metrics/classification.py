"""Classification metrics used by the paper: Precision@1, Recall, F1,
Accuracy (all macro-averaged over classes, matching the paper's tables)."""
from __future__ import annotations

import jax.numpy as jnp


def confusion_matrix(preds, labels, num_classes):
    idx = labels * num_classes + preds
    cm = jnp.bincount(idx, length=num_classes * num_classes)
    return cm.reshape(num_classes, num_classes).astype(jnp.float32)


def _prf(cm):
    tp = jnp.diag(cm)
    pred_pos = jnp.sum(cm, axis=0)
    actual_pos = jnp.sum(cm, axis=1)
    precision = tp / jnp.maximum(pred_pos, 1e-9)
    recall = tp / jnp.maximum(actual_pos, 1e-9)
    f1 = 2 * precision * recall / jnp.maximum(precision + recall, 1e-9)
    return precision, recall, f1


def precision_at_1(preds, labels, num_classes):
    cm = confusion_matrix(preds, labels, num_classes)
    p, _, _ = _prf(cm)
    return jnp.mean(p)


def recall_macro(preds, labels, num_classes):
    cm = confusion_matrix(preds, labels, num_classes)
    _, r, _ = _prf(cm)
    return jnp.mean(r)


def f1_macro(preds, labels, num_classes):
    cm = confusion_matrix(preds, labels, num_classes)
    _, _, f = _prf(cm)
    return jnp.mean(f)


def accuracy(preds, labels):
    return jnp.mean((preds == labels).astype(jnp.float32))


def classification_report(preds, labels, num_classes):
    cm = confusion_matrix(preds, labels, num_classes)
    p, r, f = _prf(cm)
    return {
        "precision@1": float(jnp.mean(p)),
        "recall": float(jnp.mean(r)),
        "f1": float(jnp.mean(f)),
        "accuracy": float(accuracy(preds, labels)) * 100.0,
        "per_class_acc": jnp.diag(cm) / jnp.maximum(jnp.sum(cm, 1), 1e-9),
    }
