"""The paper's own architectures: CIFAR ResNet-8/32/56 (Table IV)."""
from repro.models.resnet import ResNetConfig


def r8(num_classes=10):
    return ResNetConfig(depth=8, num_classes=num_classes)


def r32(num_classes=10):
    return ResNetConfig(depth=32, num_classes=num_classes)


def r56(num_classes=100):
    return ResNetConfig(depth=56, num_classes=num_classes)
