from repro.configs.registry import (
    ArchSpec, get_arch, list_archs, input_specs)
from repro.configs.shapes import SHAPES, InputShape
