"""Architecture registry: ``--arch <id>`` lookup + per-shape input specs.

Every assigned architecture registers an ``ArchSpec`` here. ``input_specs``
returns jax.ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
device allocation) for the step function selected by the input shape's kind.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, InputShape

_REGISTRY = {}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                      # transformer | xlstm | rglru | whisper
    citation: str
    make_config: Callable            # (**overrides) -> full-size config
    make_smoke_config: Callable      # () -> reduced config
    supports_long_context: bool = False   # may run long_500k
    notes: str = ""

    @property
    def model(self):
        mod = {"transformer": "repro.models.transformer",
               "xlstm": "repro.models.xlstm",
               "rglru": "repro.models.rglru",
               "whisper": "repro.models.whisper"}[self.family]
        return importlib.import_module(mod)

    def skip_reason(self, shape: InputShape) -> Optional[str]:
        if shape.name == "long_500k" and not self.supports_long_context:
            return ("pure global-attention architecture: 500k-token decode "
                    "requires a sub-quadratic / windowed variant "
                    "(DESIGN.md §5)")
        return None


def register(spec: ArchSpec):
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    return _REGISTRY[arch_id]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "minitron_8b", "qwen3_8b", "qwen2_vl_7b", "phi3_medium_14b", "gemma_7b",
    "xlstm_1_3b", "whisper_large_v3", "llama4_maverick_400b_a17b",
    "recurrentgemma_9b", "llama4_scout_17b_a16e",
]
_loaded = False


def _ensure_loaded():
    global _loaded
    if not _loaded:
        for m in _ARCH_MODULES:
            importlib.import_module(f"repro.configs.{m}")
        _loaded = True


# --------------------------------------------------------------------------
# input specs

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(spec: ArchSpec, cfg, shape: InputShape, *,
                cache_dtype=jnp.bfloat16):
    """ShapeDtypeStruct inputs for (arch, shape). Returns (kind, specs)."""
    B, S = shape.global_batch, shape.seq_len
    fam = spec.family

    if shape.kind in ("train", "prefill"):
        if fam == "whisper":
            # seq_len = encoder frames (stub frontend embeddings);
            # decoder length = whisper's 448-token context
            st = min(448, S)
            specs = {"frame_embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
                     "tokens": _sds((B, st), jnp.int32)}
            if shape.kind == "train":
                specs["labels"] = _sds((B, st), jnp.int32)
            return specs
        specs = {"tokens": _sds((B, S), jnp.int32)}
        if shape.kind == "train":
            specs["labels"] = _sds((B, S), jnp.int32)
        if fam == "transformer" and getattr(cfg, "vision_tokens", 0):
            specs["vision_embeds"] = _sds(
                (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        return specs

    # decode: one new token + carried state of size seq_len
    specs = {"tokens": _sds((B, 1), jnp.int32)}
    if fam == "whisper":
        state = jax.eval_shape(
            lambda: spec.model.init_decode_state(
                cfg, B, S, dtype=cache_dtype,
                enc_frames=cfg.max_source_positions))
    elif fam == "xlstm":
        state = jax.eval_shape(
            lambda: spec.model.init_decode_state(cfg, B))
    else:
        state = jax.eval_shape(
            lambda: spec.model.init_decode_state(cfg, B, S,
                                                 dtype=cache_dtype))
    specs["state"] = state
    return specs
