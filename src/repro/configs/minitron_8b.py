"""minitron-8b [dense] — pruned Nemotron-4 [arXiv:2407.14679].

32L, d_model 4096, 32 heads (GQA kv=8), d_ff 16384, vocab 256000.
Nemotron family: squared-ReLU MLP (ungated), RoPE, untied embeddings.
"""
from repro.configs.registry import ArchSpec, register
from repro.models.common import TransformerConfig


def make_config(**kw):
    base = dict(
        name="minitron-8b", num_layers=32, d_model=4096, num_heads=32,
        num_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=256000,
        act="relu2", rope_theta=10000.0, tie_embeddings=False)
    base.update(kw)
    return TransformerConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=256, num_heads=4,
                       num_kv_heads=2, head_dim=64, d_ff=512,
                       vocab_size=512, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="minitron-8b", family="transformer",
    citation="arXiv:2407.14679",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=False,
    notes="squared-ReLU ungated MLP (width-pruned nemotron)"))
