"""llama4-scout-17b-a16e [moe] — [hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model 5120, 40 heads (GQA kv=8), expert d_ff 8192, vocab 202048.
MoE: 16 experts, top-1 + shared expert, on EVERY layer. Same iRoPE
3-local:1-global attention pattern as maverick.
"""
from repro.configs.registry import ArchSpec, register
from repro.models.common import TransformerConfig


def make_config(**kw):
    base = dict(
        name="llama4-scout-17b-a16e", num_layers=48, d_model=5120,
        num_heads=40, num_kv_heads=8, head_dim=128, d_ff=8192,
        vocab_size=202048, act="silu", rope_theta=500_000.0,
        tie_embeddings=False, num_experts=16, moe_layer_period=1,
        moe_shared_expert=True, sliding_window=8192, global_attn_period=4)
    base.update(kw)
    return TransformerConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=256, num_heads=4,
                       num_kv_heads=2, head_dim=64, d_ff=512,
                       vocab_size=512, num_experts=4, sliding_window=8,
                       global_attn_period=2, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="llama4-scout-17b-a16e", family="transformer",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=True,
    notes="MoE 16e top-1 every layer; iRoPE 3-local:1-global"))
