"""phi3-medium-14b [dense] — [arXiv:2404.14219].

40L, d_model 5120, 40 heads (GQA kv=10), d_ff 17920, vocab 100352.
RoPE, SwiGLU, GQA, untied embeddings.
"""
from repro.configs.registry import ArchSpec, register
from repro.models.common import TransformerConfig


def make_config(**kw):
    base = dict(
        name="phi3-medium-14b", num_layers=40, d_model=5120, num_heads=40,
        num_kv_heads=10, head_dim=128, d_ff=17920, vocab_size=100352,
        act="silu", rope_theta=10000.0, tie_embeddings=False)
    base.update(kw)
    return TransformerConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=256, num_heads=4,
                       num_kv_heads=2, head_dim=64, d_ff=512,
                       vocab_size=512, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="phi3-medium-14b", family="transformer",
    citation="arXiv:2404.14219",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=False, notes="RoPE SwiGLU GQA"))
