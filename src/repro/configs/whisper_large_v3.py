"""whisper-large-v3 [audio] — [arXiv:2212.04356].

32 layers per side (encoder + decoder), d_model 1280, 20 heads, d_ff 5120,
vocab 51866. Conv/mel frontend is a STUB: input_specs supplies precomputed
frame embeddings. Decoder-only incremental decode supports decode_32k
(learned positions extended past 448 — DESIGN.md adaptation note);
long_500k is skipped (30s-audio decoder, architecturally meaningless).
"""
from repro.configs.registry import ArchSpec, register
from repro.models.whisper import WhisperConfig


def make_config(**kw):
    base = dict(
        name="whisper-large-v3", num_layers=32, d_model=1280, num_heads=20,
        num_kv_heads=20, head_dim=64, d_ff=5120, vocab_size=51866,
        max_source_positions=1500, max_target_positions=448)
    base.update(kw)
    return WhisperConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=128, num_heads=4,
                       num_kv_heads=4, head_dim=32, d_ff=256,
                       vocab_size=512, max_source_positions=32,
                       max_target_positions=32, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="whisper-large-v3", family="whisper",
    citation="arXiv:2212.04356",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=False,
    notes="enc-dec; conv frontend stubbed to frame embeddings"))
