"""qwen3-8b [dense] — [hf:Qwen/Qwen3-8B].

36L, d_model 4096, 32 heads (GQA kv=8), d_ff 12288, vocab 151936.
QK-RMSNorm on per-head q/k, SwiGLU, RoPE theta 1e6, untied embeddings.
"""
from repro.configs.registry import ArchSpec, register
from repro.models.common import TransformerConfig


def make_config(**kw):
    base = dict(
        name="qwen3-8b", num_layers=36, d_model=4096, num_heads=32,
        num_kv_heads=8, head_dim=128, d_ff=12288, vocab_size=151936,
        act="silu", qk_norm=True, rope_theta=1_000_000.0,
        tie_embeddings=False)
    base.update(kw)
    return TransformerConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=256, num_heads=4,
                       num_kv_heads=2, head_dim=64, d_ff=512,
                       vocab_size=512, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="qwen3-8b", family="transformer",
    citation="hf:Qwen/Qwen3-8B",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=False, notes="qk_norm + GQA"))
