"""qwen2-vl-7b [vlm] — [arXiv:2409.12191].

28L, d_model 3584, 28 heads (GQA kv=4), d_ff 18944, vocab 152064.
M-RoPE (temporal/height/width sections 16/24/24), qkv bias, SwiGLU.
Vision frontend is a STUB per the harness carve-out: input_specs supplies
precomputed patch embeddings (B, vision_tokens, d_model) merged after BOS.
"""
from repro.configs.registry import ArchSpec, register
from repro.models.common import TransformerConfig


def make_config(**kw):
    base = dict(
        name="qwen2-vl-7b", num_layers=28, d_model=3584, num_heads=28,
        num_kv_heads=4, head_dim=128, d_ff=18944, vocab_size=152064,
        act="silu", attn_bias=True, rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24), vision_tokens=1024,
        tie_embeddings=False)
    base.update(kw)
    return TransformerConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=256, num_heads=4,
                       num_kv_heads=2, head_dim=64, d_ff=512,
                       vocab_size=512, mrope_sections=(16, 8, 8),
                       vision_tokens=16, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="qwen2-vl-7b", family="transformer",
    citation="arXiv:2409.12191",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=False,
    notes="M-RoPE + dynamic-resolution vision (stub frontend)"))
