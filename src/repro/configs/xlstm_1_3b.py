"""xlstm-1.3b [ssm] — [arXiv:2405.04517].

48 blocks, d_model 2048, 4 heads, vocab 50304, no separate FFN (d_ff=0 in
the assignment: capacity lives in the blocks' up/down projections).
7:1 mLSTM:sLSTM ratio (one sLSTM per 8 blocks). Sub-quadratic: runs
long_500k natively (O(1)-in-S recurrent decode state).
"""
from repro.configs.registry import ArchSpec, register
from repro.models.xlstm import XLSTMConfig


def make_config(**kw):
    base = dict(
        name="xlstm-1.3b", num_layers=48, d_model=2048, num_heads=4,
        vocab_size=50304, proj_factor=2.0, slstm_every=8, conv_kernel=4,
        chunk_len=256)
    base.update(kw)
    return XLSTMConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=128, num_heads=2,
                       vocab_size=512, slstm_every=2, chunk_len=8,
                       remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="xlstm-1.3b", family="xlstm",
    citation="arXiv:2405.04517",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=True,
    notes="sLSTM sequential scan + mLSTM chunkwise-parallel"))
