"""recurrentgemma-9b [hybrid] — [arXiv:2402.19427].

38L, d_model 4096, 16 heads local-MQA (kv=1), head_dim 256, d_ff 12288,
vocab 256000. Griffin pattern (rec, rec, attn) — 12 triples + 2 trailing
recurrent blocks. RG-LRU via associative scan; local attention window 2048.
Sub-quadratic: runs long_500k (state = O(window) + O(rnn_width)).
"""
from repro.configs.registry import ArchSpec, register
from repro.models.rglru import RGLRUConfig


def make_config(**kw):
    base = dict(
        name="recurrentgemma-9b", num_layers=38, d_model=4096,
        num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
        vocab_size=256000, window=2048, conv_kernel=4)
    base.update(kw)
    return RGLRUConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=5, d_model=128, num_heads=2,
                       num_kv_heads=1, head_dim=64, d_ff=256,
                       vocab_size=512, window=8, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="recurrentgemma-9b", family="rglru",
    citation="arXiv:2402.19427",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=True,
    notes="RG-LRU + local attention 1:2; MQA kv=1"))
