"""gemma-7b [dense] — [arXiv:2403.08295].

28L, d_model 3072, 16 heads (kv=16, i.e. MHA at 7B; MQA is the 2B variant),
head_dim 256 (qkv dim 4096 > d_model — gemma's unusual wide-head layout),
d_ff 24576, GeGLU, vocab 256000, tied embeddings, sqrt(d) embedding scaling,
(1+scale) RMSNorm convention.
"""
from repro.configs.registry import ArchSpec, register
from repro.models.common import TransformerConfig


def make_config(**kw):
    base = dict(
        name="gemma-7b", num_layers=28, d_model=3072, num_heads=16,
        num_kv_heads=16, head_dim=256, d_ff=24576, vocab_size=256000,
        act="gelu", rope_theta=10000.0, tie_embeddings=True,
        embed_scale=True, norm_scale_offset=1.0)
    base.update(kw)
    return TransformerConfig(**base)


def make_smoke_config(**kw):
    return make_config(num_layers=2, d_model=256, num_heads=4,
                       num_kv_heads=4, head_dim=64, d_ff=512,
                       vocab_size=512, remat=False, **kw)


ARCH = register(ArchSpec(
    arch_id="gemma-7b", family="transformer",
    citation="arXiv:2403.08295",
    make_config=make_config, make_smoke_config=make_smoke_config,
    supports_long_context=False,
    notes="GeGLU, head_dim=256, tied embeddings, embed scaling"))
