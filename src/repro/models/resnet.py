"""CIFAR ResNet-8/32/56 (He et al. 2016), structured for splitfed learning.

Params/state are split into ``client`` and ``server`` subtrees at the paper's
cut: the client holds the initial 3x3 conv(3->16) + BN + ReLU (464 params,
475.136K flops/datapoint — Table IV), the server holds the residual stages,
the pooled head, and the classifier. BatchNorm running statistics live in a
separate ``state`` tree so the SFPL aggregation policies (RMSD / CMSD /
FedBN-exclusion) can act on them explicitly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.conv import conv2d_init, conv2d_apply
from repro.nn.linear import dense_init, dense_apply
from repro.nn.norm import (batchnorm_init, batchnorm_apply,
                           batchnorm_act_apply)


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    depth: int = 8                  # 8 / 32 / 56  (= 6n+2)
    num_classes: int = 10
    width: int = 16

    @property
    def blocks_per_stage(self) -> int:
        assert (self.depth - 2) % 6 == 0, self.depth
        return (self.depth - 2) // 6


# --------------------------------------------------------------------------
# init

def _block_init(key, cin, cout, stride):
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["conv1"] = conv2d_init(ks[0], cin, cout, 3)
    p["bn1"], s["bn1"] = batchnorm_init(ks[1], cout)
    p["conv2"] = conv2d_init(ks[2], cout, cout, 3)
    p["bn2"], s["bn2"] = batchnorm_init(ks[3], cout)
    if stride != 1 or cin != cout:
        p["proj"] = conv2d_init(ks[4], cin, cout, 1)
        p["bn_proj"], s["bn_proj"] = batchnorm_init(ks[5], cout)
    return p, s


def init(key, cfg: ResNetConfig):
    kc, kb, kf = jax.random.split(key, 3)
    w = cfg.width
    client_p = {"conv1": conv2d_init(jax.random.fold_in(kc, 0), 3, w, 3)}
    bn_p, bn_s = batchnorm_init(jax.random.fold_in(kc, 1), w)
    client_p["bn1"] = bn_p
    client_s = {"bn1": bn_s}

    server_p, server_s = {}, {}
    cin = w
    for stage, cout in enumerate([w, 2 * w, 4 * w]):
        for b in range(cfg.blocks_per_stage):
            stride = 2 if (stage > 0 and b == 0) else 1
            key_b = jax.random.fold_in(kb, stage * 100 + b)
            bp, bs = _block_init(key_b, cin, cout, stride)
            server_p[f"s{stage}b{b}"] = bp
            server_s[f"s{stage}b{b}"] = bs
            cin = cout
    server_p["fc"] = dense_init(kf, 4 * w, cfg.num_classes)
    return ({"client": client_p, "server": server_p},
            {"client": client_s, "server": server_s})


# --------------------------------------------------------------------------
# apply
#
# ``policy`` (a models.common.ComputePolicy or None) selects the compute
# path.  None keeps the original unfused f32 graph bit-for-bit (the folded
# BN affine below rounds differently, so parity-pinned callers must stay
# off it).  With a policy, convs/dense run in ``policy.compute_dtype``,
# every BN (+ following ReLU, where one exists) collapses into the fused
# ``batchnorm_act_apply`` epilogue — Pallas ``bn_act`` when
# ``policy.fused()`` — while the BN statistics stay exact f32.


def _cd(policy):
    return policy.cdtype() if policy is not None and policy.mixed else None


def _bn(p, s, x, *, training, rmsd, policy=None, relu=False, valid=None):
    if policy is None:
        y, ns = batchnorm_apply(p, s, x, training=training,
                                use_running_stats=rmsd, valid=valid)
        if relu:
            y = jax.nn.relu(y)
        return y, ns
    return batchnorm_act_apply(p, s, x, training=training, relu=relu,
                               use_running_stats=rmsd,
                               use_kernel=policy.fused(),
                               interpret=policy.kernel_interpret,
                               valid=valid)


def client_apply(params, state, x, *, training=True, rmsd=None, policy=None):
    """x: (B, 32, 32, 3) -> smashed data (B, 32, 32, w). Returns (a, state).

    With a mixed ``policy`` the smashed data comes out in the compute
    dtype — that is the tensor the collector exchanges, at half the f32
    payload bytes for bf16."""
    if policy is not None:
        x = policy.cast(x)
    h = conv2d_apply(params["conv1"], x, compute_dtype=_cd(policy))
    h, bn1 = _bn(params["bn1"], state["bn1"], h, training=training,
                 rmsd=rmsd, policy=policy, relu=True)
    return h, {"bn1": bn1}


def _block_apply(p, s, x, stride, *, training, rmsd, policy=None, valid=None):
    ns = {}
    cd = _cd(policy)
    h = conv2d_apply(p["conv1"], x, stride=stride, compute_dtype=cd)
    h, ns["bn1"] = _bn(p["bn1"], s["bn1"], h, training=training, rmsd=rmsd,
                       policy=policy, relu=True, valid=valid)
    h = conv2d_apply(p["conv2"], h, compute_dtype=cd)
    h, ns["bn2"] = _bn(p["bn2"], s["bn2"], h, training=training, rmsd=rmsd,
                       policy=policy, valid=valid)
    if "proj" in p:
        x = conv2d_apply(p["proj"], x, stride=stride, compute_dtype=cd)
        x, ns["bn_proj"] = _bn(p["bn_proj"], s["bn_proj"], x,
                               training=training, rmsd=rmsd, policy=policy,
                               valid=valid)
    return jax.nn.relu(h + x), ns


def server_apply(params, state, a, cfg: ResNetConfig, *, training=True,
                 rmsd=None, policy=None, valid=None):
    """a: smashed data (B, 32, 32, w) -> logits. Returns (logits, state).

    ``valid`` (optional ``(B,)`` bool) marks rows that belong to absent
    clients under elastic participation: they flow through the network
    (shapes are static) but are excluded from every BN batch statistic,
    so the server's state update matches a run on the surviving rows
    alone."""
    ns = {}
    h = a if policy is None else policy.cast(a)
    for stage in range(3):
        for b in range(cfg.blocks_per_stage):
            stride = 2 if (stage > 0 and b == 0) else 1
            name = f"s{stage}b{b}"
            h, ns[name] = _block_apply(params[name], state[name], h, stride,
                                       training=training, rmsd=rmsd,
                                       policy=policy, valid=valid)
    h = jnp.mean(h, axis=(1, 2))
    return dense_apply(params["fc"], h, compute_dtype=_cd(policy)), ns


def apply(params, state, x, cfg: ResNetConfig, *, training=True, rmsd=None,
          policy=None):
    a, cs = client_apply(params["client"], state["client"], x,
                         training=training, rmsd=rmsd, policy=policy)
    logits, ss = server_apply(params["server"], state["server"], a, cfg,
                              training=training, rmsd=rmsd, policy=policy)
    return logits, {"client": cs, "server": ss}


def client_flops_per_datapoint(cfg: ResNetConfig, hw=32):
    """MAC-count of the client portion (Table IV check)."""
    conv = 3 * 3 * 3 * cfg.width * hw * hw   # 3x3 conv, stride 1, SAME
    bn = 2 * cfg.width * hw * hw             # scale + shift
    return conv + bn
