"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU + local-MQA hybrid.

Block pattern is (recurrent, recurrent, local-attention) repeating — the
assigned recurrentgemma-9b has 38 layers = 12 full triples + 2 trailing
recurrent blocks. Each block is residual: temporal-mixing + GeGLU MLP.

TPU adaptation: the RG-LRU diagonal linear recurrence
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
is evaluated with ``jax.lax.associative_scan`` (log-depth, maps to efficient
TPU scans) for train/prefill, and a single fused elementwise step for decode.
Local attention uses the shared GQA layer with a 2048-token sliding window,
so decode state is O(window) and the 500k-token shape stays sub-quadratic.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import (softmax_cross_entropy, maybe_remat,
                                 constrain_act, chunked_lm_loss,
                                 constrain_dims)
from repro.nn.attention import (
    AttnConfig, attention_init, attention_apply, attention_decode,
    init_kv_cache)
from repro.nn.linear import (
    dense_init, dense_apply, embedding_init, embedding_apply,
    embedding_attend)
from repro.nn.norm import rmsnorm_init, rmsnorm_apply
from repro.nn.init import normal_init
from repro.models.xlstm import (
    causal_conv_init, causal_conv_apply, causal_conv_step)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    name: str = "recurrentgemma"
    num_layers: int = 38
    d_model: int = 4096
    num_heads: int = 16
    num_kv_heads: int = 1          # MQA
    head_dim: int = 256
    d_ff: int = 12288
    vocab_size: int = 256000
    d_rnn: int = 0                 # 0 -> d_model
    conv_kernel: int = 4
    window: int = 2048
    lru_c: float = 8.0
    norm_eps: float = 1e-6
    embed_scale: bool = True       # gemma convention
    norm_scale_offset: float = 1.0
    pattern: tuple = ("rec", "rec", "attn")
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attention_impl: str = "xla"
    remat: bool = True
    scan_layers: bool = True
    mesh_axes: tuple = None   # see common.constrain_act

    @property
    def rnn_width(self):
        return self.d_rnn or self.d_model

    @property
    def num_groups(self):
        return self.num_layers // len(self.pattern)

    @property
    def num_trailing(self):
        return self.num_layers % len(self.pattern)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _attn_cfg(cfg: RGLRUConfig):
    return AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        rope_theta=10000.0, sliding_window=cfg.window,
        impl=cfg.attention_impl, mesh_axes=cfg.mesh_axes)


# --------------------------------------------------------------------------
# RG-LRU cell

def rglru_init(key, width, dtype):
    ks = jax.random.split(key, 3)
    # Lambda init so that a ~ uniform(0.9, 0.999) at r=0.5 (griffin appendix)
    lam = normal_init(ks[0], (width,), stddev=0.5, dtype=jnp.float32) + 4.0
    return {
        "lambda": lam,
        "w_r": dense_init(ks[1], width, width, use_bias=True, dtype=dtype),
        "w_i": dense_init(ks[2], width, width, use_bias=True, dtype=dtype),
    }


def _rglru_gates(p, x, c):
    r = jax.nn.sigmoid(dense_apply(p["w_r"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["w_i"], x).astype(jnp.float32))
    log_a = -c * jax.nn.softplus(p["lambda"]) * r          # (..., width)
    a = jnp.exp(log_a)
    gated_x = i * x.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    return a, b


def rglru_apply(p, x, *, c=8.0, mesh_axes=None):
    """x: (B, S, W) -> (B, S, W) via associative scan over S."""
    a, b = _rglru_gates(p, x, c)
    a = constrain_dims(a, mesh_axes, ("dp", None, "tp"))
    b = constrain_dims(b, mesh_axes, ("dp", None, "tp"))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    del a_cum
    return h.astype(x.dtype)


def rglru_step(p, x_t, h_prev, *, c=8.0):
    """x_t: (B, W); h_prev: (B, W) fp32. Returns (y, h_new)."""
    a, b = _rglru_gates(p, x_t, c)
    h_new = a * h_prev + b
    return h_new.astype(x_t.dtype), h_new


# --------------------------------------------------------------------------
# blocks

def rec_block_init(key, cfg: RGLRUConfig):
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype()
    W = cfg.rnn_width
    return {
        "norm": rmsnorm_init(ks[0], cfg.d_model, dtype=dt),
        "up_main": dense_init(ks[1], cfg.d_model, W, use_bias=False,
                              dtype=dt),
        "up_gate": dense_init(ks[2], cfg.d_model, W, use_bias=False,
                              dtype=dt),
        "conv": causal_conv_init(ks[3], W, cfg.conv_kernel, dt),
        "lru": rglru_init(ks[4], W, dt),
        "down": dense_init(ks[5], W, cfg.d_model, use_bias=False, dtype=dt),
    }


def attn_block_init(key, cfg: RGLRUConfig):
    ks = jax.random.split(key, 2)
    return {
        "norm": rmsnorm_init(ks[0], cfg.d_model, dtype=cfg.pdtype()),
        "attn": attention_init(ks[1], _attn_cfg(cfg), dtype=cfg.pdtype()),
    }


def mlp_block_init(key, cfg: RGLRUConfig):
    ks = jax.random.split(key, 3)
    dt = cfg.pdtype()
    return {
        "norm": rmsnorm_init(ks[0], cfg.d_model, dtype=dt),
        "up": dense_init(ks[1], cfg.d_model, 2 * cfg.d_ff, use_bias=False,
                         dtype=dt),
        "down": dense_init(ks[2], cfg.d_ff, cfg.d_model, use_bias=False,
                           dtype=dt),
    }


def _mlp_apply(p, x, cfg):
    h = rmsnorm_apply(p["norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    up = dense_apply(p["up"], h)
    a, b = jnp.split(up, 2, axis=-1)
    return dense_apply(p["down"], jax.nn.gelu(a) * b).astype(x.dtype)


def rec_block_apply(p, x, cfg: RGLRUConfig):
    h = rmsnorm_apply(p["norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    main = constrain_dims(dense_apply(p["up_main"], h), cfg.mesh_axes,
                          ("dp", None, "tp"))
    gate = jax.nn.gelu(dense_apply(p["up_gate"], h))
    conv = causal_conv_apply(p["conv"], main)
    y = rglru_apply(p["lru"], conv, c=cfg.lru_c, mesh_axes=cfg.mesh_axes)
    return dense_apply(p["down"], y * gate).astype(x.dtype)


def rec_block_step(p, x_t, state, cfg: RGLRUConfig):
    """x_t: (B, 1, d). state: {conv_buf, h}."""
    h = rmsnorm_apply(p["norm"], x_t, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)[:, 0]
    main = dense_apply(p["up_main"], h)
    gate = jax.nn.gelu(dense_apply(p["up_gate"], h))
    conv_y, new_buf = causal_conv_step(p["conv"], main, state["conv_buf"])
    y, h_new = rglru_step(p["lru"], conv_y, state["h"], c=cfg.lru_c)
    out = dense_apply(p["down"], y * gate)
    return out[:, None].astype(x_t.dtype), {"conv_buf": new_buf, "h": h_new}


def attn_block_apply(p, x, cfg: RGLRUConfig, positions):
    h = rmsnorm_apply(p["norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    return attention_apply(p["attn"], h, _attn_cfg(cfg),
                           positions=positions).astype(x.dtype)


# --------------------------------------------------------------------------
# full model

def _cast(tree, cfg):
    return jax.tree_util.tree_map(
        lambda a: a.astype(cfg.cdtype())
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


def _group_init(key, cfg: RGLRUConfig, pattern):
    gp = {}
    for i, kind in enumerate(pattern):
        k1, k2, key = jax.random.split(key, 3)
        blk = (rec_block_init(k1, cfg) if kind == "rec"
               else attn_block_init(k1, cfg))
        gp[f"sub{i}"] = {"mix": blk, "mlp": mlp_block_init(k2, cfg)}
    return gp


def init(key, cfg: RGLRUConfig):
    ke, kl, kt, kn = jax.random.split(key, 4)
    params = {
        "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model,
                                dtype=cfg.pdtype()),
        "final_norm": rmsnorm_init(kn, cfg.d_model, dtype=cfg.pdtype()),
    }
    groups = [_group_init(jax.random.fold_in(kl, g), cfg, cfg.pattern)
              for g in range(cfg.num_groups)]
    params["layers"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *groups)
    if cfg.num_trailing:
        params["trailing"] = _group_init(
            kt, cfg, cfg.pattern[:cfg.num_trailing])
    return params


def _apply_group(gp, x, cfg, positions, pattern, *, remat=False):
    def one(x, sub, kind):
        sub = _cast(sub, cfg)
        if kind == "rec":
            x = x + rec_block_apply(sub["mix"], x, cfg)
        else:
            x = x + attn_block_apply(sub["mix"], x, cfg, positions)
        return x + _mlp_apply(sub["mlp"], x, cfg)

    for i, kind in enumerate(pattern):
        f = (jax.checkpoint(lambda x_, s_, kind=kind: one(x_, s_, kind))
             if remat else (lambda x_, s_, kind=kind: one(x_, s_, kind)))
        x = f(x, gp[f"sub{i}"])
    return x


def unembed(params, x, cfg: RGLRUConfig):
    logits = embedding_attend(params["embed"], x, compute_dtype=cfg.cdtype())
    return constrain_act(logits, cfg, kind="logits")


def forward(params, batch_in, cfg: RGLRUConfig, *, training=True,
            return_hidden=False, last_token_only=False):
    tokens = batch_in["tokens"]
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype())
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def group_fn(x, gp):
        x = _apply_group(gp, x, cfg, positions, cfg.pattern,
                         remat=cfg.remat and training)
        return constrain_act(x, cfg), None

    body = group_fn   # per-block remat happens inside _apply_group
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        for g in range(cfg.num_groups):
            gp = jax.tree_util.tree_map(lambda a, g=g: a[g], params["layers"])
            x, _ = body(x, gp)
    if cfg.num_trailing:
        x = _apply_group(params["trailing"], x, cfg, positions,
                         cfg.pattern[:cfg.num_trailing])
    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    if last_token_only:
        x = x[:, -1:]
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    return unembed(params, x, cfg).astype(jnp.float32), \
        jnp.zeros((), jnp.float32)


def loss_fn(params, batch_in, cfg: RGLRUConfig, *, training=True):
    hidden, _ = forward(params, batch_in, cfg, training=training,
                        return_hidden=True)
    loss = chunked_lm_loss(hidden, batch_in["labels"],
                           lambda xc: unembed(params, xc, cfg))
    return loss, {"xent": loss}


# --------------------------------------------------------------------------
# decode

def _rec_state_init(cfg, batch):
    return {
        "conv_buf": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.rnn_width),
                              cfg.cdtype()),
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
    }


def _attn_state_init(cfg, batch, max_len, dtype):
    slots = min(max_len, cfg.window)
    return init_kv_cache(batch, slots, cfg.num_kv_heads, cfg.head_dim,
                         dtype=dtype)


def init_decode_state(cfg: RGLRUConfig, batch, max_len,
                      *, dtype=jnp.bfloat16):
    state = {"groups": {}, "trailing": {}}
    for i, kind in enumerate(cfg.pattern):
        one = (_rec_state_init(cfg, batch) if kind == "rec"
               else _attn_state_init(cfg, batch, max_len, dtype))
        state["groups"][f"sub{i}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None],
                                       (cfg.num_groups,) + a.shape), one)
    for i, kind in enumerate(cfg.pattern[:cfg.num_trailing]):
        state["trailing"][f"sub{i}"] = (
            _rec_state_init(cfg, batch) if kind == "rec"
            else _attn_state_init(cfg, batch, max_len, dtype))
    return state


def _step_group(gp, gs, x, cfg, cur_pos, pattern):
    ns = {}
    for i, kind in enumerate(pattern):
        sub = _cast(gp[f"sub{i}"], cfg)
        if kind == "rec":
            d, ns[f"sub{i}"] = rec_block_step(sub["mix"], x,
                                              gs[f"sub{i}"], cfg)
            x = x + d
        else:
            h = rmsnorm_apply(sub["mix"]["norm"], x, eps=cfg.norm_eps,
                              scale_offset=cfg.norm_scale_offset)
            d, ns[f"sub{i}"] = attention_decode(
                sub["mix"]["attn"], h, _attn_cfg(cfg),
                cache=gs[f"sub{i}"], cur_pos=cur_pos)
            x = x + d.astype(x.dtype)
        x = x + _mlp_apply(sub["mlp"], x, cfg)
    return x, ns


def decode_step(params, state, tokens, cfg: RGLRUConfig, *, cur_pos):
    x = embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype())
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)

    def group_fn(x, scanned):
        gp, gs = scanned
        return _step_group(gp, gs, x, cfg, cur_pos, cfg.pattern)

    x, new_groups = jax.lax.scan(group_fn, x,
                                 (params["layers"], state["groups"]))
    new_state = {"groups": new_groups, "trailing": {}}
    if cfg.num_trailing:
        x, new_state["trailing"] = _step_group(
            params["trailing"], state["trailing"], x, cfg, cur_pos,
            cfg.pattern[:cfg.num_trailing])
    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    logits = embedding_attend(params["embed"], x, compute_dtype=cfg.cdtype())
    return logits.astype(jnp.float32), new_state
