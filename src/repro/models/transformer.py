"""Decoder-only transformer family.

One parameterized definition covers the dense GQA archs (minitron-8b,
qwen3-8b, phi3-medium-14b, gemma-7b), the MoE archs (llama4-maverick /
llama4-scout: top-1 MoE with shared expert, iRoPE 3-local:1-global attention
pattern), and the VLM backbone (qwen2-vl-7b: M-RoPE + stub vision embeddings).

Layers are grouped into scan units of ``cfg.group_size`` (the lcm of the
MoE-period and attention-pattern period) so heterogeneous layer patterns
remain scannable: per-group params are stacked on a leading ``num_groups``
axis and the whole stack is traversed with one ``jax.lax.scan`` (bounded HLO,
fast multi-pod compiles), with activation remat around each group.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import (
    TransformerConfig, softmax_cross_entropy, maybe_remat, constrain_act,
    chunked_lm_loss)
from repro.nn.attention import (
    AttnConfig, attention_init, attention_apply, attention_decode,
    init_kv_cache)
from repro.nn.linear import (
    dense_init, dense_apply, embedding_init, embedding_apply,
    embedding_attend)
from repro.nn.mlp import mlp_init, mlp_apply
from repro.nn.moe import moe_init, moe_apply, router_load_balance_loss
from repro.nn.norm import rmsnorm_init, rmsnorm_apply
from repro.nn.rope import apply_rope  # noqa: F401 (re-export convenience)


# --------------------------------------------------------------------------
# config plumbing

def _attn_cfg(cfg: TransformerConfig, window):
    return AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        qk_norm=cfg.qk_norm, use_bias=cfg.attn_bias,
        rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
        sliding_window=window, impl=cfg.attention_impl,
        mesh_axes=cfg.mesh_axes)


# --------------------------------------------------------------------------
# init

def _layer_init(key, cfg: TransformerConfig, kind):
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype()
    p = {
        "attn_norm": rmsnorm_init(ks[0], cfg.d_model, dtype=dt),
        "attn": attention_init(ks[1], _attn_cfg(cfg, kind["window"]),
                               dtype=dt),
        "mlp_norm": rmsnorm_init(ks[2], cfg.d_model, dtype=dt),
    }
    if kind["moe"]:
        p["moe"] = moe_init(ks[3], cfg.d_model, cfg.d_ff, cfg.num_experts,
                            shared_expert=cfg.moe_shared_expert, dtype=dt)
    else:
        gated = cfg.act in ("silu", "gelu")
        dff = cfg.d_ff_dense or cfg.d_ff
        p["mlp"] = mlp_init(ks[3], cfg.d_model, dff, gated=gated, dtype=dt)
    return p


def init(key, cfg: TransformerConfig):
    G = cfg.group_size
    assert cfg.num_layers % G == 0, (cfg.num_layers, G)
    num_groups = cfg.num_layers // G
    k_embed, k_norm, k_unembed, k_layers = jax.random.split(key, 4)
    params = {
        "embed": embedding_init(k_embed, cfg.vocab_size, cfg.d_model,
                                dtype=cfg.pdtype()),
        "final_norm": rmsnorm_init(k_norm, cfg.d_model, dtype=cfg.pdtype()),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k_unembed, cfg.d_model,
                                       cfg.vocab_size, use_bias=False,
                                       dtype=cfg.pdtype())

    layer_keys = jax.random.split(k_layers, num_groups * G)

    def one_group(g):
        return {
            f"sub{p}": _layer_init(layer_keys[g * G + p], cfg,
                                   cfg.layer_kind(p))
            for p in range(G)
        }

    groups = [one_group(g) for g in range(num_groups)]
    params["layers"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *groups)
    return params


# --------------------------------------------------------------------------
# forward

def cast_for_compute(tree, cfg: TransformerConfig):
    """Cast float params to the compute dtype (router weights stay fp32)."""
    def leafcast(path, a):
        if any(getattr(k, "key", None) == "router" for k in path):
            return a
        if jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(cfg.cdtype())
        return a
    return jax.tree_util.tree_map_with_path(leafcast, tree)


def _layer_apply(lp, x, cfg: TransformerConfig, kind, positions, training):
    lp = cast_for_compute(lp, cfg)
    h = rmsnorm_apply(lp["attn_norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    x = x + attention_apply(lp["attn"], h, _attn_cfg(cfg, kind["window"]),
                            positions=positions)
    h = rmsnorm_apply(lp["mlp_norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    aux_loss = jnp.zeros((), jnp.float32)
    if kind["moe"]:
        dp_groups = 1
        if cfg.mesh_axes:
            for a, n in cfg.mesh_axes:
                if a != "model":
                    dp_groups *= n
        y, aux = moe_apply(lp["moe"], h, num_experts=cfg.num_experts,
                           capacity_factor=cfg.capacity_factor, act=cfg.act,
                           dp_groups=dp_groups, mesh_axes=cfg.mesh_axes)
        if training and cfg.router_aux_coef:
            aux_loss = router_load_balance_loss(
                aux["router_logits"], aux["expert_id"], cfg.num_experts)
    else:
        y = mlp_apply(lp["mlp"], h, act=cfg.act)
    return x + y, aux_loss


def build_mrope_positions(batch, seq, vision_tokens):
    """Deterministic M-RoPE ids for the stub-frontend layout
    [text BOS][vision grid][text...]: vision tokens share t=1 and take (h, w)
    grid ids; text ids advance all three streams together."""
    gh = max(1, int(math.sqrt(max(vision_tokens, 1))))
    gw = -(-vision_tokens // gh) if vision_tokens else 1
    idx = jnp.arange(seq)
    is_vis = (idx >= 1) & (idx < 1 + vision_tokens)
    v = jnp.clip(idx - 1, 0, max(vision_tokens - 1, 0))
    t_id = jnp.where(is_vis, 1, idx - jnp.where(idx >= 1 + vision_tokens,
                                                vision_tokens - 1, 0))
    h_id = jnp.where(is_vis, 1 + v // gw, t_id)
    w_id = jnp.where(is_vis, 1 + v % gw, t_id)
    pos = jnp.stack([t_id, h_id, w_id]).astype(jnp.int32)     # (3, S)
    return jnp.broadcast_to(pos[:, None], (3, batch, seq))


def _default_positions(cfg, batch, seq):
    if cfg.mrope_sections is not None:
        return build_mrope_positions(batch, seq, cfg.vision_tokens)
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))


def embed_inputs(params, batch_in, cfg: TransformerConfig):
    """Token embeddings with optional VLM stub-frontend merge."""
    tokens = batch_in["tokens"]
    B, S = tokens.shape
    x = embedding_apply(params["embed"], tokens,
                        compute_dtype=cfg.cdtype())
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.vision_tokens:
        ve = batch_in["vision_embeds"].astype(x.dtype)   # (B, Nv, d)
        nv = ve.shape[1]
        x = jax.lax.dynamic_update_slice(x, ve, (0, 1, 0))
        del nv
    return x


def unembed(params, x, cfg: TransformerConfig):
    if cfg.tie_embeddings:
        logits = embedding_attend(params["embed"], x,
                                  compute_dtype=cfg.cdtype())
    else:
        logits = dense_apply(params["unembed"], x,
                             compute_dtype=cfg.cdtype())
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return constrain_act(logits, cfg, kind="logits")


def forward(params, batch_in, cfg: TransformerConfig, *, training=True,
            collector_perm=None, cut_groups=1, return_hidden=False,
            last_token_only=False):
    """batch_in: {tokens (B,S) [, vision_embeds (B,Nv,d)]} -> (logits, aux).

    ``collector_perm``: SFPL's global-collector shuffle for split-LM
    training — a permutation of the global batch applied to the smashed
    data after the first ``cut_groups`` scan groups (the client-side model
    portion). With the batch axis sharded over ("pod","data") the gather
    lowers to all-to-all; its VJP is the de-shuffling scatter, so
    Algorithm 1's gradient routing falls out of autodiff. Labels must be
    permuted by the caller (see core.split_lm.sfpl_lm_loss).
    """
    tokens = batch_in["tokens"]
    B, S = tokens.shape
    x = constrain_act(embed_inputs(params, batch_in, cfg), cfg)
    positions = batch_in.get("positions", _default_positions(cfg, B, S))
    G = cfg.group_size

    def group_fn(x, gp):
        aux_total = jnp.zeros((), jnp.float32)
        for p in range(G):
            f = lambda x_, lp, p=p: _layer_apply(
                lp, x_, cfg, cfg.layer_kind(p), positions, training)
            if cfg.remat and training and G > 1:
                f = jax.checkpoint(f)   # per-layer remat inside the group
            x, aux = f(x, gp[f"sub{p}"])
            aux_total = aux_total + aux
        return constrain_act(x, cfg), aux_total

    # remat policy: per-layer checkpoints inside multi-layer groups, one
    # outer checkpoint when G == 1 — nesting both double-recomputes.
    scan_body = maybe_remat(group_fn, cfg.remat and training and G == 1)
    num_groups = cfg.num_layers // G

    def run_groups(x, layer_params, lo, hi):
        sliced = jax.tree_util.tree_map(lambda a: a[lo:hi], layer_params)
        if cfg.scan_layers:
            return jax.lax.scan(scan_body, x, sliced)
        aux_loss = jnp.zeros((), jnp.float32)
        for g in range(hi - lo):
            gp = jax.tree_util.tree_map(lambda a, g=g: a[g], sliced)
            x, aux = scan_body(x, gp)
            aux_loss = aux_loss + aux
        return x, aux_loss

    if collector_perm is not None:
        # client-side portion -> smashed data -> global collector shuffle
        x, aux1 = run_groups(x, params["layers"], 0, cut_groups)
        x = jnp.take(x, collector_perm, axis=0)
        x, aux2 = run_groups(x, params["layers"], cut_groups, num_groups)
        aux_loss = jnp.sum(aux1) + jnp.sum(aux2)
    else:
        x, aux = run_groups(x, params["layers"], 0, num_groups)
        aux_loss = jnp.sum(aux)

    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    if last_token_only:
        x = x[:, -1:]
    if return_hidden:
        return x, aux_loss
    return unembed(params, x, cfg).astype(jnp.float32), aux_loss


def loss_fn(params, batch_in, cfg: TransformerConfig, *, training=True):
    hidden, aux_loss = forward(params, batch_in, cfg, training=training,
                               return_hidden=True)
    loss = chunked_lm_loss(hidden, batch_in["labels"],
                           lambda xc: unembed(params, xc, cfg))
    return loss + cfg.router_aux_coef * aux_loss, {"xent": loss,
                                                   "aux": aux_loss}


# --------------------------------------------------------------------------
# decode (KV cache)

def init_decode_state(cfg: TransformerConfig, batch, max_len,
                      *, dtype=jnp.bfloat16):
    """Stacked per-group KV caches. SWA layers get window-sized ring slots."""
    G = cfg.group_size
    num_groups = cfg.num_layers // G
    cache = {}
    for p in range(G):
        kind = cfg.layer_kind(p)
        slots = min(max_len, kind["window"] or max_len)
        one = init_kv_cache(batch, slots, cfg.num_kv_heads, cfg.head_dim,
                            dtype=dtype)
        cache[f"sub{p}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (num_groups,) + a.shape),
            one)
    return cache


def decode_step(params, state, tokens, cfg: TransformerConfig, *, cur_pos):
    """tokens: (B, 1); state: cache pytree; cur_pos: scalar int32 position.

    Returns (logits (B, 1, V), new_state)."""
    B = tokens.shape[0]
    x = embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype())
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    G = cfg.group_size

    def group_fn(x, scanned):
        gp, gcache = scanned
        new_cache = {}
        for p in range(G):
            lp = cast_for_compute(gp[f"sub{p}"], cfg)
            kind = cfg.layer_kind(p)
            h = rmsnorm_apply(lp["attn_norm"], x, eps=cfg.norm_eps,
                              scale_offset=cfg.norm_scale_offset)
            attn_out, new_cache[f"sub{p}"] = attention_decode(
                lp["attn"], h, _attn_cfg(cfg, kind["window"]),
                cache=gcache[f"sub{p}"], cur_pos=cur_pos)
            x = x + attn_out
            h = rmsnorm_apply(lp["mlp_norm"], x, eps=cfg.norm_eps,
                              scale_offset=cfg.norm_scale_offset)
            if kind["moe"]:
                y, _ = moe_apply(lp["moe"], h, num_experts=cfg.num_experts,
                                 capacity_factor=cfg.capacity_factor,
                                 act=cfg.act)
            else:
                y = mlp_apply(lp["mlp"], h, act=cfg.act)
            x = x + y
        return x, new_cache

    x, new_state = jax.lax.scan(group_fn, x, (params["layers"], state))
    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps,
                      scale_offset=cfg.norm_scale_offset)
    if cfg.tie_embeddings:
        logits = embedding_attend(params["embed"], x,
                                  compute_dtype=cfg.cdtype())
    else:
        logits = dense_apply(params["unembed"], x,
                             compute_dtype=cfg.cdtype())
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits.astype(jnp.float32), new_state
