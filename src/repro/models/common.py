"""Shared model utilities: loss, config base, remat/scan helpers."""
from __future__ import annotations

import dataclasses
import logging
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

logger = logging.getLogger(__name__)

IGNORE_LABEL = -100

# Read once at import time (an explicit keyword default), NOT inside the
# traced loss body — an env mutation between traces must not silently
# change an already-compiled graph's chunking.
DEFAULT_CE_CHUNKS = int(os.environ.get("REPRO_CE_CHUNKS", "8"))


@dataclasses.dataclass(frozen=True)
class ComputePolicy:
    """Mixed-precision policy for the split-model compute path.

    ``compute_dtype`` sets the matmul/conv/elementwise dtype for the
    client forward and the server forward-backward; master params, the
    BatchNorm statistics (batch AND running — the paper's CMSD/RMSD local
    inference policies need exact f32 moments), and the loss accumulation
    always stay f32.  With a non-f32 compute dtype the smashed-data
    exchange also travels the collector's ``all_to_all`` in that dtype —
    half the payload bytes for bf16.

    ``wire_dtype`` narrows the exchange payload INDEPENDENTLY of the
    compute dtype (``core.wire.WIRE_DTYPE_NAMES``): the smashed rows are
    quantized/cast immediately before each collective and restored to the
    compute dtype immediately after, so f32 compute with an int8 wire is
    a valid (and the paper-relevant constrained-uplink) configuration.
    ``wire_dtype_bwd`` does the same for the routed-back gradient rows —
    separate because the backward leg is usually the more
    quantization-sensitive one (default ``None`` = exact).

    ``use_fused_kernels`` follows the repo-wide ``None`` = auto-on-TPU
    convention and gates the fused Pallas ``bn_act`` / ``softmax_xent``
    epilogues; ``kernel_interpret`` forces Pallas interpret mode so the
    fused path can run (slowly) in CPU CI.
    """
    compute_dtype: str = "float32"
    use_fused_kernels: Optional[bool] = None
    kernel_interpret: bool = False
    wire_dtype: Optional[str] = None
    wire_dtype_bwd: Optional[str] = None

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def mixed(self) -> bool:
        return self.cdtype() != jnp.float32

    def cast(self, x):
        """Cast an activation into the compute dtype (no-op at f32)."""
        return x.astype(self.cdtype()) if self.mixed else x

    def fused(self) -> bool:
        from repro.kernels._compat import auto_use_kernel
        return auto_use_kernel(self.use_fused_kernels)


def softmax_cross_entropy(logits, labels, *, ignore=IGNORE_LABEL,
                          z_loss_coef: float = 0.0):
    """logits: (..., V) ; labels: (...,) int32. Mean over non-ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels != ignore
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss_coef:
        loss = loss + z_loss_coef * lse ** 2
    loss = jnp.where(valid, loss, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(loss) / denom


def chunked_lm_loss(hidden, labels, unembed_fn, *, chunks=DEFAULT_CE_CHUNKS,
                    ignore=IGNORE_LABEL):
    """Cross-entropy over a large vocab without materializing full logits.

    ``hidden``: (B, S, d) final-norm output; ``unembed_fn(x) -> logits``.
    The sequence axis is split into ``chunks`` (default
    ``DEFAULT_CE_CHUNKS``, the ``REPRO_CE_CHUNKS`` env value at import
    time); each chunk's logits + loss are wrapped in jax.checkpoint, so
    the backward recomputes one chunk's logits at a time — peak logits
    memory drops by ~``chunks``x. This is a beyond-paper memory
    optimization recorded in EXPERIMENTS.md §Perf.
    """
    if chunks is None:
        chunks = DEFAULT_CE_CHUNKS
    B, S, d = hidden.shape
    requested = chunks
    while chunks > 1 and S % chunks != 0:
        chunks -= 1
    if chunks != requested:
        logger.warning(
            "chunked_lm_loss: seq len %d not divisible by chunks=%d; "
            "reduced to %d", S, requested, chunks)

    def one(xc, lc):
        logits = unembed_fn(xc).astype(jnp.float32)
        valid = lc != ignore
        safe = jnp.where(valid, lc, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        loss = jnp.where(valid, lse - ll, 0.0)
        return jnp.sum(loss), jnp.sum(valid)

    one = jax.checkpoint(one)
    Sc = S // chunks
    total = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.int32)
    for i in range(chunks):
        t, c = one(hidden[:, i * Sc:(i + 1) * Sc],
                   labels[:, i * Sc:(i + 1) * Sc])
        total = total + t
        count = count + c
    return total / jnp.maximum(count, 1)


def accuracy_from_logits(logits, labels, *, ignore=IGNORE_LABEL):
    valid = labels != ignore
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == labels) & valid
    return jnp.sum(hit) / jnp.maximum(jnp.sum(valid), 1)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """One config covers dense / GQA / MoE / VLM decoder variants."""
    name: str = "transformer"
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024
    act: str = "silu"                       # "gelu" -> GeGLU
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    norm_eps: float = 1e-6
    norm_scale_offset: float = 0.0          # gemma: 1.0  ((1+scale) rmsnorm)
    embed_scale: bool = False               # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    logit_softcap: float = 0.0              # gemma-2 style; 0 = off
    # MoE
    num_experts: int = 0
    d_ff_dense: int = 0                     # llama4 dense-layer MLP; 0=d_ff
    moe_layer_period: int = 1               # maverick: 2 (alternate layers)
    moe_shared_expert: bool = True
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # attention pattern (llama4 iRoPE: 3 local chunked + 1 global)
    sliding_window: Optional[int] = None
    global_attn_period: int = 0             # 0 = all layers same window
    # execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attention_impl: str = "xla"
    remat: bool = True
    scan_layers: bool = True
    # activation sharding: ((axis, size), ...) or None (single device).
    # When set, residual-stream activations are sequence-sharded over the
    # "model" axis (Megatron sequence parallelism) and logits are
    # vocab-sharded — both essential to fit 16 GB/chip at 1M-token batches.
    mesh_axes: Optional[Tuple[Tuple[str, int], ...]] = None
    # vlm stub frontend
    vision_tokens: int = 0                  # >0 -> expects vision_embeds input

    @property
    def group_size(self) -> int:
        """Layers per scan group (lcm of layer-pattern periods)."""
        g = 1
        if self.num_experts and self.moe_layer_period > 1:
            g = _lcm(g, self.moe_layer_period)
        if self.global_attn_period:
            g = _lcm(g, self.global_attn_period)
        return g

    def layer_kind(self, idx: int) -> dict:
        """Static description of layer ``idx``'s flavour."""
        is_moe = bool(self.num_experts) and (
            (idx + 1) % max(self.moe_layer_period, 1) == 0)
        if self.global_attn_period:
            is_global = (idx + 1) % self.global_attn_period == 0
            window = None if is_global else self.sliding_window
        else:
            window = self.sliding_window
        return {"moe": is_moe, "window": window}

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _lcm(a, b):
    import math
    return a * b // math.gcd(a, b)


def maybe_remat(fn, enabled):
    if not enabled:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def constrain_dims(x, mesh_axes, roles):
    """Generic per-dim sharding constraint. roles: tuple of 'dp'|'tp'|None
    per dim (guarded by divisibility; no-op without mesh_axes)."""
    if not mesh_axes:
        return x
    from jax.sharding import PartitionSpec as P
    sizes = dict(mesh_axes)
    dp = tuple(a for a, _ in mesh_axes if a != "model")
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    tp = sizes.get("model", 1)
    spec = []
    for role, dim in zip(roles, x.shape):
        if role == "dp" and dim % dp_size == 0 and dim >= dp_size:
            spec.append(dp)
        elif role == "tp" and dim % tp == 0 and dim >= tp:
            spec.append("model")
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_act(x, cfg, kind="residual"):
    """Sharding constraints on activations (no-op when cfg.mesh_axes unset
    or when a dim is not divisible by the assigned axis).

    kinds: "residual" (B,S,d) -> (dp, "model", None)   [sequence parallel]
           "logits"   (B,S,V) -> (dp, None, "model")   [vocab sharded]
    """
    axes = getattr(cfg, "mesh_axes", None)
    if not axes:
        return x
    from jax.sharding import PartitionSpec as P
    sizes = dict(axes)
    dp = tuple(a for a, _ in axes if a != "model")
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]
    tp = sizes.get("model", 1)

    b_ok = x.shape[0] % dp_size == 0 and x.shape[0] >= dp_size
    spec = [dp if b_ok else None, None, None]
    if kind == "residual":
        if x.shape[1] % tp == 0 and x.shape[1] >= tp:
            spec[1] = "model"
        elif not b_ok and x.shape[1] % (dp_size * tp) == 0:
            # batch=1 long-context: shard the sequence over everything
            spec[1] = dp + ("model",)
    elif kind == "logits":
        if x.shape[-1] % tp == 0:
            spec[-1] = "model"
        if not b_ok and x.shape[1] % dp_size == 0 and x.shape[1] >= dp_size:
            spec[1] = dp
    return jax.lax.with_sharding_constraint(x, P(*spec))


def count_params(params):
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
