"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the harness carve-out, the modality frontend (mel-spectrogram + 2-conv
feature extractor) is a STUB: ``input_specs`` supplies precomputed frame
embeddings (B, frames, d_model) directly. We implement the transformer that
consumes them: a bidirectional encoder with sinusoidal positions and a causal
decoder with learned positions, cross-attention, LayerNorm and GELU MLPs.

Adaptation note (DESIGN.md): real whisper caps the decoder at 448 learned
positions; for the assigned decode_32k shape we extend the learned position
table to the requested cache length — an architectural stretch, exercised in
the dry-run only.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import (softmax_cross_entropy, maybe_remat,
                                 constrain_act, chunked_lm_loss)
from repro.nn.attention import (
    AttnConfig, attention_init, attention_apply, attention_decode,
    init_kv_cache)
from repro.nn.linear import (
    dense_init, dense_apply, embedding_init, embedding_apply,
    embedding_attend)
from repro.nn.norm import layernorm_init, layernorm_apply
from repro.nn.mlp import mlp_init, mlp_apply


@dataclasses.dataclass(frozen=True)
class WhisperConfig:
    name: str = "whisper"
    num_layers: int = 32            # per side (encoder and decoder)
    d_model: int = 1280
    num_heads: int = 20
    num_kv_heads: int = 20
    head_dim: int = 64
    d_ff: int = 5120
    vocab_size: int = 51866
    max_source_positions: int = 1500
    max_target_positions: int = 448
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attention_impl: str = "xla"
    remat: bool = True
    scan_layers: bool = True
    mesh_axes: tuple = None   # see common.constrain_act

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _attn_cfg(cfg: WhisperConfig):
    return AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
        use_bias=True, use_rope=False, impl=cfg.attention_impl,
        mesh_axes=cfg.mesh_axes)


def sinusoidal_positions(length, dim):
    """Whisper encoder's fixed sinusoidal table, (length, dim) fp32."""
    log_timescale = math.log(10000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# --------------------------------------------------------------------------
# init

def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype()
    return {
        "attn_norm": layernorm_init(ks[0], cfg.d_model, dtype=dt),
        "attn": attention_init(ks[1], _attn_cfg(cfg), dtype=dt),
        "mlp_norm": layernorm_init(ks[2], cfg.d_model, dtype=dt),
        "mlp": mlp_init(ks[3], cfg.d_model, cfg.d_ff, gated=False, dtype=dt),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 6)
    dt = cfg.pdtype()
    return {
        "self_norm": layernorm_init(ks[0], cfg.d_model, dtype=dt),
        "self_attn": attention_init(ks[1], _attn_cfg(cfg), dtype=dt),
        "cross_norm": layernorm_init(ks[2], cfg.d_model, dtype=dt),
        "cross_attn": attention_init(ks[3], _attn_cfg(cfg), dtype=dt),
        "mlp_norm": layernorm_init(ks[4], cfg.d_model, dtype=dt),
        "mlp": mlp_init(ks[5], cfg.d_model, cfg.d_ff, gated=False, dtype=dt),
    }


def init(key, cfg: WhisperConfig, *, max_target_positions=None):
    mtp = max_target_positions or cfg.max_target_positions
    ks = jax.random.split(key, 6)
    enc_layers = [_enc_layer_init(jax.random.fold_in(ks[0], i), cfg)
                  for i in range(cfg.num_layers)]
    dec_layers = [_dec_layer_init(jax.random.fold_in(ks[1], i), cfg)
                  for i in range(cfg.num_layers)]
    stack = lambda ls: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ls)
    return {
        "enc_layers": stack(enc_layers),
        "dec_layers": stack(dec_layers),
        "enc_norm": layernorm_init(ks[2], cfg.d_model, dtype=cfg.pdtype()),
        "dec_norm": layernorm_init(ks[3], cfg.d_model, dtype=cfg.pdtype()),
        "embed": embedding_init(ks[4], cfg.vocab_size, cfg.d_model,
                                dtype=cfg.pdtype()),
        "pos_embed": embedding_init(ks[5], mtp, cfg.d_model,
                                    dtype=cfg.pdtype()),
    }


def _cast(tree, cfg):
    return jax.tree_util.tree_map(
        lambda a: a.astype(cfg.cdtype())
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


# --------------------------------------------------------------------------
# encoder

def encode(params, frame_embeds, cfg: WhisperConfig, *, training=True):
    """frame_embeds: (B, Sf, d) stub-frontend output -> encoder states."""
    B, Sf, _ = frame_embeds.shape
    x = frame_embeds.astype(cfg.cdtype())
    x = x + sinusoidal_positions(Sf, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(Sf, dtype=jnp.int32), (B, Sf))

    def layer_fn(x, lp):
        lp = _cast(lp, cfg)
        h = layernorm_apply(lp["attn_norm"], x, eps=cfg.norm_eps)
        x = x + attention_apply(lp["attn"], h, _attn_cfg(cfg),
                                positions=positions, causal=False)
        h = layernorm_apply(lp["mlp_norm"], x, eps=cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, act="gelu")
        return constrain_act(x, cfg), None

    body = maybe_remat(layer_fn, cfg.remat and training)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        params["enc_layers"])
            x, _ = body(x, lp)
    return layernorm_apply(params["enc_norm"], x, eps=cfg.norm_eps)


# --------------------------------------------------------------------------
# decoder

def decode_train(params, tokens, enc_states, cfg: WhisperConfig, *,
                 training=True, return_hidden=False):
    """Teacher-forced decoder pass. tokens: (B, St)."""
    B, St = tokens.shape
    x = embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype())
    pos_ids = jnp.arange(St, dtype=jnp.int32)
    x = x + embedding_apply(params["pos_embed"], pos_ids,
                            compute_dtype=cfg.cdtype())[None]
    positions = jnp.broadcast_to(pos_ids, (B, St))
    enc_kv = enc_states.astype(cfg.cdtype())

    def layer_fn(x, lp):
        lp = _cast(lp, cfg)
        h = layernorm_apply(lp["self_norm"], x, eps=cfg.norm_eps)
        x = x + attention_apply(lp["self_attn"], h, _attn_cfg(cfg),
                                positions=positions, causal=True)
        h = layernorm_apply(lp["cross_norm"], x, eps=cfg.norm_eps)
        k, v = _cross_kv(lp["cross_attn"], enc_kv, cfg)
        x = x + attention_apply(lp["cross_attn"], h, _attn_cfg(cfg),
                                positions=positions,
                                kv_override=(k, v, None))
        h = layernorm_apply(lp["mlp_norm"], x, eps=cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, act="gelu")
        return constrain_act(x, cfg), None

    body = maybe_remat(layer_fn, cfg.remat and training)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i],
                                        params["dec_layers"])
            x, _ = body(x, lp)
    x = layernorm_apply(params["dec_norm"], x, eps=cfg.norm_eps)
    if return_hidden:
        return x
    return unembed(params, x, cfg).astype(jnp.float32)


def unembed(params, x, cfg: WhisperConfig):
    logits = embedding_attend(params["embed"], x, compute_dtype=cfg.cdtype())
    return constrain_act(logits, cfg, kind="logits")


def _cross_kv(ap, enc_states, cfg):
    B, Sf, _ = enc_states.shape
    K, D = cfg.num_kv_heads, cfg.head_dim
    k = dense_apply(ap["wk"], enc_states).reshape(B, Sf, K, D)
    v = dense_apply(ap["wv"], enc_states).reshape(B, Sf, K, D)
    return k, v


def forward(params, batch_in, cfg: WhisperConfig, *, training=True,
            return_hidden=False, last_token_only=False):
    """batch_in: {frame_embeds (B,Sf,d), tokens (B,St)[, labels]}."""
    enc = encode(params, batch_in["frame_embeds"], cfg, training=training)
    hidden = decode_train(params, batch_in["tokens"], enc, cfg,
                          training=training, return_hidden=True)
    if last_token_only:
        hidden = hidden[:, -1:]
    if return_hidden:
        return hidden, jnp.zeros((), jnp.float32)
    return unembed(params, hidden, cfg).astype(jnp.float32), \
        jnp.zeros((), jnp.float32)


def loss_fn(params, batch_in, cfg: WhisperConfig, *, training=True):
    hidden, _ = forward(params, batch_in, cfg, training=training,
                        return_hidden=True)
    loss = chunked_lm_loss(hidden, batch_in["labels"],
                           lambda xc: unembed(params, xc, cfg))
    return loss, {"xent": loss}


# --------------------------------------------------------------------------
# incremental decode (self-attn KV cache + precomputed cross KV)

def init_decode_state(cfg: WhisperConfig, batch, max_len, *,
                      dtype=jnp.bfloat16, enc_frames=None):
    ef = enc_frames or cfg.max_source_positions
    one = init_kv_cache(batch, max_len, cfg.num_kv_heads, cfg.head_dim,
                        dtype=dtype)
    L = cfg.num_layers
    return {
        "self": jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), one),
        "cross_k": jnp.zeros((L, batch, ef, cfg.num_kv_heads, cfg.head_dim),
                             dtype),
        "cross_v": jnp.zeros((L, batch, ef, cfg.num_kv_heads, cfg.head_dim),
                             dtype),
    }


def prefill_cross(params, enc_states, state, cfg: WhisperConfig):
    """Populate per-layer cross-attention K/V from encoder states."""
    enc = enc_states.astype(cfg.cdtype())

    def layer_fn(_, lp):
        lp = _cast(lp, cfg)
        k, v = _cross_kv(lp["cross_attn"], enc, cfg)
        return None, (k.astype(state["cross_k"].dtype),
                      v.astype(state["cross_v"].dtype))

    _, (ks, vs) = jax.lax.scan(layer_fn, None, params["dec_layers"])
    return dict(state, cross_k=ks, cross_v=vs)


def decode_step(params, state, tokens, cfg: WhisperConfig, *, cur_pos):
    """One decoder token. tokens: (B, 1)."""
    B = tokens.shape[0]
    x = embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype())
    x = x + embedding_apply(params["pos_embed"],
                            jnp.full((B, 1), cur_pos, jnp.int32),
                            compute_dtype=cfg.cdtype())

    def layer_fn(x, scanned):
        lp, cache, ck, cv = scanned
        lp = _cast(lp, cfg)
        h = layernorm_apply(lp["self_norm"], x, eps=cfg.norm_eps)
        d, new_cache = attention_decode(lp["self_attn"], h, _attn_cfg(cfg),
                                        cache=cache, cur_pos=cur_pos)
        x = x + d.astype(x.dtype)
        h = layernorm_apply(lp["cross_norm"], x, eps=cfg.norm_eps)
        x = x + attention_apply(lp["cross_attn"], h, _attn_cfg(cfg),
                                positions=jnp.full((B, 1), cur_pos,
                                                   jnp.int32),
                                kv_override=(ck, cv, None)).astype(x.dtype)
        h = layernorm_apply(lp["mlp_norm"], x, eps=cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, act="gelu")
        return x, new_cache

    x, new_self = jax.lax.scan(
        layer_fn, x,
        (params["dec_layers"], state["self"], state["cross_k"],
         state["cross_v"]))
    x = layernorm_apply(params["dec_norm"], x, eps=cfg.norm_eps)
    logits = embedding_attend(params["embed"], x, compute_dtype=cfg.cdtype())
    return logits.astype(jnp.float32), dict(state, self=new_self)
