"""xLSTM (arXiv:2405.04517): mLSTM + sLSTM residual blocks, 7:1 ratio.

TPU adaptation notes (DESIGN.md §4): the paper's CUDA kernels are replaced by
  * mLSTM — a **chunkwise-parallel** form (linear-attention style): the
    sequence is split into chunks of ``chunk_len``; within a chunk the
    stabilized quadratic form runs on the MXU, across chunks a (C, n, m)
    matrix-memory state is carried through ``jax.lax.scan``. Memory is
    O(S * chunk) instead of O(S^2), which is what makes prefill_32k and the
    500k-token long-context shape lowerable.
  * sLSTM — inherently sequential (hidden-to-hidden recurrence): a
    ``lax.scan`` over time with per-head block-diagonal recurrent matrices.
Decode carries per-layer recurrent states; there is no KV cache, so
long_500k decode state is O(1) in sequence length.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import (softmax_cross_entropy, maybe_remat,
                                 constrain_act, chunked_lm_loss,
                                 constrain_dims)
from repro.nn.linear import (
    dense_init, dense_apply, embedding_init, embedding_apply,
    embedding_attend)
from repro.nn.norm import rmsnorm_init, rmsnorm_apply
from repro.nn.init import lecun_normal, normal_init, zeros_init


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    name: str = "xlstm"
    num_layers: int = 48
    d_model: int = 2048
    num_heads: int = 4
    vocab_size: int = 50304
    proj_factor: float = 2.0        # mLSTM up-projection
    slstm_ff_factor: float = 4.0 / 3.0
    conv_kernel: int = 4
    qkv_blocksize: int = 4          # block-diagonal qkv (official xlstm)
    slstm_every: int = 8            # one sLSTM per 8 blocks (7:1)
    chunk_len: int = 256
    slstm_impl: str = "xla"        # "xla" | "pallas" | "pallas_interpret"
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    mesh_axes: tuple = None   # see common.constrain_act

    @property
    def d_inner(self):
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self):
        return self.d_inner // self.num_heads

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


# --------------------------------------------------------------------------
# causal depthwise conv (kernel 4) with decode ring buffer

def causal_conv_init(key, dim, kernel, dtype):
    return {"w": normal_init(key, (kernel, dim), stddev=0.1, dtype=dtype),
            "b": zeros_init(key, (dim,), dtype=dtype)}


def causal_conv_apply(p, x):
    """x: (B, S, D) depthwise causal conv."""
    k = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]] * p["w"][i].astype(x.dtype)
    return out + p["b"].astype(x.dtype)


def causal_conv_step(p, x_t, buf):
    """x_t: (B, D); buf: (B, k-1, D) previous inputs. Returns (y, new_buf)."""
    k = p["w"].shape[0]
    window = jnp.concatenate([buf, x_t[:, None]], axis=1)       # (B, k, D)
    y = jnp.einsum("bkd,kd->bd", window, p["w"].astype(x_t.dtype))
    y = y + p["b"].astype(x_t.dtype)
    return y, window[:, -(k - 1):]


# --------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel + recurrent step

def _mlstm_chunk_scan(q, k, v, i_pre, logf, *, chunk, mesh_axes=None):
    """q,k,v: (B, H, S, D) (q pre-scaled); i_pre/logf: (B, H, S) fp32.

    Returns h: (B, H, S, D). Chunkwise stabilized linear-attention form.
    """
    B, H, S, D = q.shape
    assert S % chunk == 0, (S, chunk)
    N = S // chunk
    rs = lambda a: a.reshape(B, H, N, chunk, *a.shape[3:]).swapaxes(0, 2)
    qc, kc, vc = rs(q), rs(k), rs(v)           # (N, H, B, chunk, D)
    ic, fc = rs(i_pre), rs(logf)               # (N, H, B, chunk)

    def chunk_fn(carry, xs):
        C, n, m = carry                        # (H,B,D,D), (H,B,D), (H,B)
        qj, kj, vj, ij, fj = xs
        Bcum = jnp.cumsum(fj, axis=-1)                       # (H,B,L)
        # intra-chunk exponents  D_ts = Bcum_t - Bcum_s + i_s  (s <= t)
        Dmat = (Bcum[..., :, None] - Bcum[..., None, :] + ij[..., None, :])
        L = Dmat.shape[-1]
        tri = jnp.tril(jnp.ones((L, L), bool))
        Dmat = jnp.where(tri, Dmat, -jnp.inf)
        m_intra = jnp.max(Dmat, axis=-1)                     # (H,B,L)
        m_inter = m[..., None] + Bcum                        # (H,B,L)
        m_t = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)

        smat = jnp.einsum("hbtd,hbsd->hbts", qj.astype(jnp.float32),
                          kj.astype(jnp.float32))
        smat = smat * jnp.exp(Dmat - m_t[..., None])         # (H,B,t,s)
        inter_scale = jnp.exp(m_inter - m_t)                 # (H,B,L)
        h_num = (jnp.einsum("hbts,hbsd->hbtd", smat, vj.astype(jnp.float32))
                 + jnp.einsum("hbtd,hbde->hbte", qj.astype(jnp.float32), C)
                 * inter_scale[..., None])
        denom = (jnp.sum(smat, axis=-1)
                 + jnp.einsum("hbtd,hbd->hbt", qj.astype(jnp.float32), n)
                 * inter_scale)
        denom = jnp.maximum(jnp.abs(denom), jnp.exp(-m_t))
        h = h_num / denom[..., None]

        # state update to end of chunk
        m_next = jnp.maximum(m + Bcum[..., -1],
                             jnp.max(Bcum[..., -1:] - Bcum + ij, axis=-1))
        w_state = jnp.exp(m + Bcum[..., -1] - m_next)        # (H,B)
        w_tok = jnp.exp(Bcum[..., -1:] - Bcum + ij - m_next[..., None])
        C_new = (C * w_state[..., None, None]
                 + jnp.einsum("hbs,hbsd,hbse->hbde", w_tok,
                              kj.astype(jnp.float32), vj.astype(jnp.float32)))
        n_new = (n * w_state[..., None]
                 + jnp.einsum("hbs,hbsd->hbd", w_tok, kj.astype(jnp.float32)))
        # shard the (H,B,D,D) matrix memory: B over dp, and the OUTPUT
        # (value) D dim over model — the query einsum contracts the first D,
        # so sharding the second keeps the chunk scan communication-free
        # (hypothesis log: sharding the contracted dim cost an all-gather of
        # C per chunk iteration).
        C_new = constrain_dims(C_new, mesh_axes, (None, "dp", None, "tp"))
        n_new = constrain_dims(n_new, mesh_axes, (None, "dp", "tp"))
        return (C_new, n_new, m_next), h

    C0 = jnp.zeros((H, B, D, D), jnp.float32)
    n0 = jnp.zeros((H, B, D), jnp.float32)
    m0 = jnp.full((H, B), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk_fn, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 2).reshape(B, H, S, D)
    return h


def mlstm_recurrent_step(state, q_t, k_t, v_t, i_pre, logf):
    """One decode step. state: (C (B,H,D,D), n (B,H,D), m (B,H)) fp32;
    q/k/v_t: (B,H,D) (q pre-scaled); i_pre/logf: (B,H)."""
    C, n, m = state
    m_new = jnp.maximum(logf + m, i_pre)
    f_s = jnp.exp(logf + m - m_new)[..., None]
    i_s = jnp.exp(i_pre - m_new)[..., None]
    kf = k_t.astype(jnp.float32)
    vf = v_t.astype(jnp.float32)
    C_new = C * f_s[..., None] + i_s[..., None] * kf[..., :, None] \
        * vf[..., None, :]
    n_new = n * f_s + i_s * kf
    qf = q_t.astype(jnp.float32)
    h_num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    denom = jnp.maximum(jnp.abs(jnp.sum(qf * n_new, -1)), jnp.exp(-m_new))
    h = h_num / denom[..., None]
    return (C_new, n_new, m_new), h


# --------------------------------------------------------------------------
# block-diagonal projection (official xlstm qkv_proj_blocksize)

def blockdiag_init(key, dim, blocksize, dtype):
    nb = dim // blocksize
    return {"w": normal_init(key, (nb, blocksize, blocksize),
                             stddev=1.0 / math.sqrt(blocksize), dtype=dtype)}


def blockdiag_apply(p, x):
    """x: (..., dim) -> block-diagonal linear, dim = nb * bs."""
    nb, bs, _ = p["w"].shape
    xs = x.reshape(x.shape[:-1] + (nb, bs))
    y = jnp.einsum("...nb,nbc->...nc", xs, p["w"].astype(x.dtype))
    return y.reshape(x.shape)


# --------------------------------------------------------------------------
# mLSTM block

def mlstm_block_init(key, cfg: XLSTMConfig):
    ks = jax.random.split(key, 9)
    dt = cfg.pdtype()
    di = cfg.d_inner
    return {
        "norm": rmsnorm_init(ks[0], cfg.d_model, dtype=dt),
        "up_main": dense_init(ks[1], cfg.d_model, di, use_bias=False,
                              dtype=dt),
        "up_gate": dense_init(ks[2], cfg.d_model, di, use_bias=False,
                              dtype=dt),
        "conv": causal_conv_init(ks[3], di, cfg.conv_kernel, dt),
        "wq": blockdiag_init(ks[4], di, cfg.qkv_blocksize, dt),
        "wk": blockdiag_init(ks[5], di, cfg.qkv_blocksize, dt),
        "wv": blockdiag_init(ks[6], di, cfg.qkv_blocksize, dt),
        "gates": dense_init(ks[7], di, 2 * cfg.num_heads, use_bias=True,
                            dtype=jnp.float32),
        "head_norm": rmsnorm_init(ks[8], di, dtype=dt),
        "down": dense_init(jax.random.fold_in(key, 99), di, cfg.d_model,
                           use_bias=False, dtype=dt),
    }


def _mlstm_qkv_gates(p, x_in, cfg: XLSTMConfig, conv_out):
    """Shared projection logic. conv_out: (B,S,di) post-conv activations."""
    B, S, _ = conv_out.shape
    H, D = cfg.num_heads, cfg.head_dim
    q = blockdiag_apply(p["wq"], conv_out).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    k = blockdiag_apply(p["wk"], conv_out).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    v = blockdiag_apply(p["wv"], x_in).reshape(B, S, H, D).transpose(0, 2, 1, 3)
    q = q / math.sqrt(D)
    gates = dense_apply(p["gates"], conv_out.astype(jnp.float32))  # (B,S,2H)
    i_pre = gates[..., :H].transpose(0, 2, 1)        # (B,H,S)
    f_pre = gates[..., H:].transpose(0, 2, 1)
    logf = jax.nn.log_sigmoid(f_pre)
    return q, k, v, i_pre.astype(jnp.float32), logf.astype(jnp.float32)


def mlstm_block_apply(p, x, cfg: XLSTMConfig):
    """x: (B, S, d_model) -> (B, S, d_model) residual branch output."""
    B, S, _ = x.shape
    h = rmsnorm_apply(p["norm"], x, eps=cfg.norm_eps)
    main = constrain_dims(dense_apply(p["up_main"], h), cfg.mesh_axes,
                          ("dp", None, "tp"))
    gate = constrain_dims(dense_apply(p["up_gate"], h), cfg.mesh_axes,
                          ("dp", None, "tp"))
    conv_out = jax.nn.silu(causal_conv_apply(p["conv"], main))
    q, k, v, i_pre, logf = _mlstm_qkv_gates(p, main, cfg, conv_out)
    # (B,H,S,D) -> chunk scan in fp32
    hcell = _mlstm_chunk_scan(q, k, v, i_pre, logf,
                              chunk=min(cfg.chunk_len, S),
                              mesh_axes=cfg.mesh_axes)
    hcell = hcell.transpose(0, 2, 1, 3).reshape(B, S, cfg.d_inner)
    hcell = rmsnorm_apply(p["head_norm"], hcell.astype(x.dtype),
                          eps=cfg.norm_eps)
    out = dense_apply(p["down"], hcell * jax.nn.silu(gate))
    return out.astype(x.dtype)


def mlstm_block_step(p, x_t, state, cfg: XLSTMConfig):
    """x_t: (B, 1, d). state: {conv_buf, C, n, m}. Returns (out, state)."""
    B = x_t.shape[0]
    h = rmsnorm_apply(p["norm"], x_t, eps=cfg.norm_eps)[:, 0]    # (B, d)
    main = dense_apply(p["up_main"], h)
    gate = dense_apply(p["up_gate"], h)
    conv_y, new_buf = causal_conv_step(p["conv"], main, state["conv_buf"])
    conv_out = jax.nn.silu(conv_y)
    H, D = cfg.num_heads, cfg.head_dim
    q = blockdiag_apply(p["wq"], conv_out).reshape(B, H, D) / math.sqrt(D)
    k = blockdiag_apply(p["wk"], conv_out).reshape(B, H, D)
    v = blockdiag_apply(p["wv"], main).reshape(B, H, D)
    gates = dense_apply(p["gates"], conv_out.astype(jnp.float32))
    i_pre = gates[..., :H].astype(jnp.float32)
    logf = jax.nn.log_sigmoid(gates[..., H:]).astype(jnp.float32)
    (C, n, m), hcell = mlstm_recurrent_step(
        (state["C"], state["n"], state["m"]), q, k, v, i_pre, logf)
    hcell = hcell.reshape(B, cfg.d_inner).astype(x_t.dtype)
    hcell = rmsnorm_apply(p["head_norm"], hcell, eps=cfg.norm_eps)
    out = dense_apply(p["down"], hcell * jax.nn.silu(gate))
    return out[:, None].astype(x_t.dtype), \
        {"conv_buf": new_buf, "C": C, "n": n, "m": m}


def mlstm_state_init(cfg: XLSTMConfig, batch):
    H, D = cfg.num_heads, cfg.head_dim
    return {
        "conv_buf": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner),
                              cfg.cdtype()),
        "C": jnp.zeros((batch, H, D, D), jnp.float32),
        "n": jnp.zeros((batch, H, D), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM block

def slstm_block_init(key, cfg: XLSTMConfig):
    ks = jax.random.split(key, 10)
    dt = cfg.pdtype()
    d = cfg.d_model
    H = cfg.num_heads
    Dh = d // H
    dff = int(d * cfg.slstm_ff_factor)
    p = {"norm": rmsnorm_init(ks[0], d, dtype=dt),
         "head_norm": rmsnorm_init(ks[1], d, dtype=dt)}
    for gi, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = dense_init(ks[2 + gi], d, d, use_bias=True, dtype=dt)
        p[f"r_{g}"] = normal_init(jax.random.fold_in(ks[6], gi),
                                  (H, Dh, Dh), stddev=1.0 / math.sqrt(Dh),
                                  dtype=dt)
    p["ff_norm"] = rmsnorm_init(ks[7], d, dtype=dt)
    p["ff_up"] = dense_init(ks[8], d, 2 * dff, use_bias=False, dtype=dt)
    p["ff_down"] = dense_init(ks[9], dff, d, use_bias=False, dtype=dt)
    return p


def _slstm_cell_step(p, carry, x_pre, H, Dh):
    """carry: (c, n, m, h) each (B, H, Dh) fp32 (m: (B,H,Dh));
    x_pre: dict gate->(B, H, Dh) input preactivations."""
    c, n, m, h = carry
    rec = {g: jnp.einsum("bhd,hde->bhe", h,
                         p[f"r_{g}"].astype(jnp.float32))
           for g in ("i", "f", "z", "o")}
    i_pre = x_pre["i"] + rec["i"]
    f_pre = x_pre["f"] + rec["f"]
    z = jnp.tanh(x_pre["z"] + rec["z"])
    o = jax.nn.sigmoid(x_pre["o"] + rec["o"])
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = jnp.maximum(f_s * n + i_s, 1e-6)
    h_new = o * (c_new / n_new)
    return (c_new, n_new, m_new, h_new)


def slstm_block_apply(p, x, cfg: XLSTMConfig):
    """x: (B, S, d) -> (B, S, d). Sequential scan over time."""
    B, S, d = x.shape
    H = cfg.num_heads
    Dh = d // H
    hin = rmsnorm_apply(p["norm"], x, eps=cfg.norm_eps)
    import os
    _tp = None if os.environ.get("REPRO_SLSTM_NO_TP") else "tp"
    pre = {g: constrain_dims(
        dense_apply(p[f"w_{g}"], hin).astype(jnp.float32)
        .reshape(B, S, H, Dh), cfg.mesh_axes, ("dp", None, None, _tp))
        for g in ("i", "f", "z", "o")}

    if cfg.slstm_impl in ("pallas", "pallas_interpret"):
        # fused on-chip time loop (kernels/slstm_scan): state in VMEM,
        # head-local layout, no per-step collectives
        from repro.kernels.slstm_scan.ops import slstm_scan
        R = jnp.stack([p[f"r_{g}"].astype(jnp.float32)
                       for g in ("i", "f", "z", "o")])
        hs_k = slstm_scan(pre["i"], pre["f"], pre["z"], pre["o"], R,
                          interpret=(cfg.slstm_impl == "pallas_interpret"))
        hcell = hs_k.reshape(B, S, d).astype(x.dtype)
        hcell = rmsnorm_apply(p["head_norm"], hcell, eps=cfg.norm_eps)
        out = x + hcell
        hf = rmsnorm_apply(p["ff_norm"], out, eps=cfg.norm_eps)
        up = dense_apply(p["ff_up"], hf)
        a, b = jnp.split(up, 2, axis=-1)
        ff = dense_apply(p["ff_down"], jax.nn.gelu(a) * b)
        return (out + ff - x).astype(x.dtype)

    def step(carry, xs):
        new = _slstm_cell_step(p, carry, xs, H, Dh)
        return new, new[3]

    zero = jnp.zeros((B, H, Dh), jnp.float32)
    carry0 = (zero, zero + 1e-6, zero - 1e30, zero)
    xs = {g: pre[g].swapaxes(0, 1) for g in pre}      # (S, B, H, Dh)
    _, hs = jax.lax.scan(step, carry0, xs)
    hcell = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    hcell = rmsnorm_apply(p["head_norm"], hcell, eps=cfg.norm_eps)
    out = x + hcell
    # post-FFN (GeGLU, pf 4/3)
    hf = rmsnorm_apply(p["ff_norm"], out, eps=cfg.norm_eps)
    up = dense_apply(p["ff_up"], hf)
    a, b = jnp.split(up, 2, axis=-1)
    ff = dense_apply(p["ff_down"], jax.nn.gelu(a) * b)
    return (out + ff - x).astype(x.dtype)   # return residual-branch delta


def slstm_block_step(p, x_t, state, cfg: XLSTMConfig):
    B = x_t.shape[0]
    d = cfg.d_model
    H = cfg.num_heads
    Dh = d // H
    hin = rmsnorm_apply(p["norm"], x_t, eps=cfg.norm_eps)[:, 0]
    pre = {g: dense_apply(p[f"w_{g}"], hin).astype(jnp.float32)
           .reshape(B, H, Dh) for g in ("i", "f", "z", "o")}
    carry = (state["c"], state["n"], state["m"], state["h"])
    c, n, m, h = _slstm_cell_step(p, carry, pre, H, Dh)
    hcell = h.reshape(B, d).astype(x_t.dtype)
    hcell = rmsnorm_apply(p["head_norm"], hcell, eps=cfg.norm_eps)
    out = x_t[:, 0] + hcell
    hf = rmsnorm_apply(p["ff_norm"], out, eps=cfg.norm_eps)
    up = dense_apply(p["ff_up"], hf)
    a, b = jnp.split(up, 2, axis=-1)
    ff = dense_apply(p["ff_down"], jax.nn.gelu(a) * b)
    delta = (out + ff - x_t[:, 0])[:, None]
    return delta.astype(x_t.dtype), {"c": c, "n": n, "m": m, "h": h}


def slstm_state_init(cfg: XLSTMConfig, batch):
    H = cfg.num_heads
    Dh = cfg.d_model // H
    zero = jnp.zeros((batch, H, Dh), jnp.float32)
    return {"c": zero, "n": zero + 1e-6, "m": zero - 1e30, "h": zero}


# --------------------------------------------------------------------------
# full model

def init(key, cfg: XLSTMConfig):
    G = cfg.slstm_every
    assert cfg.num_layers % G == 0
    num_groups = cfg.num_layers // G
    ke, kl, kn = jax.random.split(key, 3)
    params = {
        "embed": embedding_init(ke, cfg.vocab_size, cfg.d_model,
                                dtype=cfg.pdtype()),
        "final_norm": rmsnorm_init(kn, cfg.d_model, dtype=cfg.pdtype()),
    }
    keys = jax.random.split(kl, num_groups * G)

    def one_group(g):
        gp = {}
        for p_idx in range(G):
            k = keys[g * G + p_idx]
            if p_idx == G - 1:
                gp[f"sub{p_idx}"] = slstm_block_init(k, cfg)
            else:
                gp[f"sub{p_idx}"] = mlstm_block_init(k, cfg)
        return gp

    groups = [one_group(g) for g in range(num_groups)]
    params["layers"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *groups)
    return params


def _cast(tree, cfg):
    return jax.tree_util.tree_map(
        lambda a: a.astype(cfg.cdtype())
        if jnp.issubdtype(a.dtype, jnp.floating) else a, tree)


def unembed(params, x, cfg: XLSTMConfig):
    logits = embedding_attend(params["embed"], x, compute_dtype=cfg.cdtype())
    return constrain_act(logits, cfg, kind="logits")


def forward(params, batch_in, cfg: XLSTMConfig, *, training=True,
            return_hidden=False, last_token_only=False):
    tokens = batch_in["tokens"]
    x = embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype())
    G = cfg.slstm_every

    def group_fn(x, gp):
        def one(x_, lp, p_idx):
            lp = _cast(lp, cfg)
            if p_idx == G - 1:
                return x_ + slstm_block_apply(lp, x_, cfg)
            return x_ + mlstm_block_apply(lp, x_, cfg)

        for p_idx in range(G):
            f = (jax.checkpoint(lambda x_, lp, p=p_idx: one(x_, lp, p))
                 if cfg.remat and training
                 else (lambda x_, lp, p=p_idx: one(x_, lp, p)))
            x = f(x, gp[f"sub{p_idx}"])
        return constrain_act(x, cfg), None

    body = group_fn   # per-block remat inside group_fn
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        num_groups = cfg.num_layers // G
        for g in range(num_groups):
            gp = jax.tree_util.tree_map(lambda a, g=g: a[g], params["layers"])
            x, _ = body(x, gp)
    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    if last_token_only:
        x = x[:, -1:]
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    return unembed(params, x, cfg).astype(jnp.float32), \
        jnp.zeros((), jnp.float32)


def loss_fn(params, batch_in, cfg: XLSTMConfig, *, training=True):
    hidden, _ = forward(params, batch_in, cfg, training=training,
                        return_hidden=True)
    loss = chunked_lm_loss(hidden, batch_in["labels"],
                           lambda xc: unembed(params, xc, cfg))
    return loss, {"xent": loss}


def init_decode_state(cfg: XLSTMConfig, batch, max_len=None, *, dtype=None):
    del max_len, dtype     # recurrent: state is O(1) in sequence length
    G = cfg.slstm_every
    num_groups = cfg.num_layers // G
    state = {}
    for p_idx in range(G):
        if p_idx == G - 1:
            one = slstm_state_init(cfg, batch)
        else:
            one = mlstm_state_init(cfg, batch)
        state[f"sub{p_idx}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (num_groups,) + a.shape),
            one)
    return state


def decode_step(params, state, tokens, cfg: XLSTMConfig, *, cur_pos=None):
    del cur_pos  # recurrent models are position-free
    x = embedding_apply(params["embed"], tokens, compute_dtype=cfg.cdtype())
    G = cfg.slstm_every

    def group_fn(x, scanned):
        gp, gs = scanned
        ns = {}
        for p_idx in range(G):
            lp = _cast(gp[f"sub{p_idx}"], cfg)
            if p_idx == G - 1:
                d, ns[f"sub{p_idx}"] = slstm_block_step(
                    lp, x, gs[f"sub{p_idx}"], cfg)
            else:
                d, ns[f"sub{p_idx}"] = mlstm_block_step(
                    lp, x, gs[f"sub{p_idx}"], cfg)
            x = x + d
        return x, ns

    x, new_state = jax.lax.scan(group_fn, x, (params["layers"], state))
    x = rmsnorm_apply(params["final_norm"], x, eps=cfg.norm_eps)
    logits = embedding_attend(params["embed"], x, compute_dtype=cfg.cdtype())
    return logits.astype(jnp.float32), new_state
