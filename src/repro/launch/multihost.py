"""Multi-host (multi-process) runtime wiring for the pod collector mesh.

One JAX process per pod: ``initialize`` joins the distributed runtime,
``make_pod_mesh`` builds the 2-D ``("pod", "data")`` collector mesh
(``engine_dist.make_data_mesh`` with ``pods`` defaulting to the process
count — ``jax.make_mesh`` orders devices process-major, so pod ``p`` IS
process ``p``'s local devices), and the epoch entrypoints run unchanged:
every process executes the same program over the same replicated host
inputs (keys, perms, probed slacks are derived identically everywhere),
with state placed by ``engine_dist.shard_dcml_state`` — each process
contributes the addressable slice of its own pod.

Typical worker (run once per host, e.g. under tests/_multihost.py):

    from repro.launch import multihost
    multihost.initialize("10.0.0.1:8476", num_processes=2, process_id=pid)
    mesh = multihost.make_pod_mesh()          # (pods, local_device_count)
    st = ED.shard_dcml_state(st0, mesh)
    epoch = ED.make_sfpl_epoch_sharded(..., mesh=mesh, ...)

Functions here never touch jax device state at import time (same contract
as ``launch.mesh``).
"""
from __future__ import annotations

import logging
import os

import jax
import numpy as np

from repro.core import engine_dist as ED
from repro.core.retry import retry_call

logger = logging.getLogger(__name__)


def initialize(coordinator_address, num_processes, process_id, *,
               local_devices=None, cpu_collectives="gloo",
               connect_attempts=5, connect_base_delay=0.5,
               connect_max_delay=8.0, sleep=None):
    """Join the JAX distributed runtime — call before ANY other jax use.

    ``local_devices`` forces this process's CPU device count via
    ``XLA_FLAGS`` (appended only if the flag is not already set — the
    backend reads it once, so it must land before first device use).
    ``cpu_collectives`` selects the CPU cross-process collective
    implementation: the default backend cannot run multi-process
    collectives at all, so "gloo" is the working default. It is a config
    flag, NOT an environment variable — the env spelling is silently
    ignored, which is why this helper sets it explicitly.

    The coordinator join races the coordinator's listen socket (and, on a
    real fleet, any transient fabric fault), so it runs under
    ``core.retry.retry_call``: ``connect_attempts`` tries with jittered
    exponential backoff between ``connect_base_delay`` and
    ``connect_max_delay`` seconds, the jitter seeded by ``process_id`` so
    simultaneous joiners decorrelate deterministically. After the budget a
    ``core.retry.RetryError`` names the join, the budget, and the last
    underlying error. ``sleep`` injects a test clock."""
    if (local_devices is not None
            and "xla_force_host_platform_device_count"
            not in os.environ.get("XLA_FLAGS", "")):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={local_devices}"
        ).strip()
    jax.config.update("jax_cpu_collectives_implementation", cpu_collectives)
    kw = {} if sleep is None else {"sleep": sleep}

    def _join():
        try:
            jax.distributed.initialize(
                coordinator_address=coordinator_address,
                num_processes=num_processes, process_id=process_id)
        except Exception:
            # a failed connect can leave the distributed client half-set,
            # which would turn every retry into "already initialized" —
            # reset it so the next attempt starts clean
            try:
                jax.distributed.shutdown()
            except Exception:
                pass
            raise

    retry_call(
        _join,
        attempts=connect_attempts, base_delay=connect_base_delay,
        max_delay=connect_max_delay, seed=process_id,
        retry_on=(RuntimeError, ConnectionError, TimeoutError),
        describe=(f"jax.distributed join (process {process_id}/"
                  f"{num_processes} -> {coordinator_address})"), **kw)
    logger.info("joined distributed runtime: process %d/%d at %s",
                process_id, num_processes, coordinator_address)


def make_pod_mesh(num_shards=None, *, pods=None, axis="data",
                  pod_axis="pod"):
    """The 2-D ``(pods, num_shards // pods)`` collector mesh over
    ``(pod_axis, axis)``; ``pods`` defaults to ``jax.process_count()``
    (one pod per host process) and ``num_shards`` to every global
    device."""
    pods = jax.process_count() if pods is None else pods
    num_shards = num_shards or len(jax.devices())
    return ED.make_data_mesh(num_shards, pods=pods, axis=axis,
                             pod_axis=pod_axis)


def host_value(x):
    """Fetch a (possibly non-fully-addressable) array to every host as
    numpy: single-process arrays convert directly, multi-host replicated
    arrays read any local copy, and multi-host sharded arrays are
    allgathered (every process gets the full pod-major value)."""
    try:
        return np.asarray(x)
    except RuntimeError:
        if getattr(x, "is_fully_replicated", False):
            return np.asarray(x.addressable_data(0))
        from jax.experimental import multihost_utils
        return np.asarray(multihost_utils.process_allgather(x, tiled=True))
