"""LM evaluation: perplexity / token accuracy over a token stream.

Usage:
  PYTHONPATH=src python -m repro.launch.eval --arch qwen3-8b --batches 8
"""
from __future__ import annotations

import argparse
import math

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import synthetic_token_stream
from repro.models.common import softmax_cross_entropy


def evaluate_lm(spec, cfg, params, *, batches=8, batch=8, seq=64, seed=0):
    """Returns {loss, ppl, token_accuracy} over the synthetic stream."""
    model = spec.model
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def eval_batch(params, batch_in):
        logits, _ = model.forward(params, batch_in, cfg, training=False)
        loss = softmax_cross_entropy(logits, batch_in["labels"])
        acc = jnp.mean(
            (jnp.argmax(logits, -1) == batch_in["labels"]).astype(
                jnp.float32))
        return loss, acc

    tot_loss, tot_acc = 0.0, 0.0
    for i in range(batches):
        key, kd = jax.random.split(key)
        toks, labels = synthetic_token_stream(kd, batch=batch, seq_len=seq,
                                              vocab=cfg.vocab_size)
        b = {"tokens": toks, "labels": labels}
        if spec.family == "whisper":
            b["frame_embeds"] = jax.random.normal(
                kd, (batch, 16, cfg.d_model), jnp.float32)
        if getattr(cfg, "vision_tokens", 0):
            b["vision_embeds"] = jax.random.normal(
                kd, (batch, cfg.vision_tokens, cfg.d_model))
        loss, acc = eval_batch(params, b)
        tot_loss += float(loss)
        tot_acc += float(acc)
    loss = tot_loss / batches
    return {"loss": loss, "ppl": math.exp(min(loss, 30.0)),
            "token_accuracy": tot_acc / batches}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--ckpt")
    args = ap.parse_args()
    spec = get_arch(args.arch)
    cfg = spec.make_smoke_config()
    params = spec.model.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import restore_checkpoint
        params, step = restore_checkpoint(args.ckpt, params)
        print(f"restored step {step}")
    m = evaluate_lm(spec, cfg, params, batches=args.batches)
    print(f"{args.arch}: loss {m['loss']:.4f}  ppl {m['ppl']:.1f}  "
          f"token-acc {m['token_accuracy']:.3f}")


if __name__ == "__main__":
    main()
