"""Evaluation drivers.

LM mode (default): perplexity / token accuracy over a token stream.
Paper mode (``--paper``): the SFPL-vs-SFLv2 comparison AT MATCHED FLEET
SIZE — both schemes trained through the same placement-agnostic round
engine (optionally ``--sharded`` on a mesh over all visible devices) and
evaluated on the same held-out set, the comparison the IoT end-to-end
evaluation (arXiv:2003.13376) argues is the only meaningful one.

Usage:
  PYTHONPATH=src python -m repro.launch.eval --arch qwen3-8b --batches 8
  PYTHONPATH=src python -m repro.launch.eval --paper [--sharded] \
      [--clients 8] [--epochs 4] [--alpha 1.0] \
      [--pipeline double_buffered] [--submesh]
"""
from __future__ import annotations

import argparse
import math

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import synthetic_token_stream
from repro.models.common import softmax_cross_entropy


def evaluate_lm(spec, cfg, params, *, batches=8, batch=8, seq=64, seed=0):
    """Returns {loss, ppl, token_accuracy} over the synthetic stream."""
    model = spec.model
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def eval_batch(params, batch_in):
        logits, _ = model.forward(params, batch_in, cfg, training=False)
        loss = softmax_cross_entropy(logits, batch_in["labels"])
        acc = jnp.mean(
            (jnp.argmax(logits, -1) == batch_in["labels"]).astype(
                jnp.float32))
        return loss, acc

    tot_loss, tot_acc = 0.0, 0.0
    for i in range(batches):
        key, kd = jax.random.split(key)
        toks, labels = synthetic_token_stream(kd, batch=batch, seq_len=seq,
                                              vocab=cfg.vocab_size)
        b = {"tokens": toks, "labels": labels}
        if spec.family == "whisper":
            b["frame_embeds"] = jax.random.normal(
                kd, (batch, 16, cfg.d_model), jnp.float32)
        if getattr(cfg, "vision_tokens", 0):
            b["vision_embeds"] = jax.random.normal(
                kd, (batch, cfg.vision_tokens, cfg.d_model))
        loss, acc = eval_batch(params, b)
        tot_loss += float(loss)
        tot_acc += float(acc)
    loss = tot_loss / batches
    return {"loss": loss, "ppl": math.exp(min(loss, 30.0)),
            "token_accuracy": tot_acc / batches}


def evaluate_paper(*, num_clients=8, epochs=4, batch_size=8, sharded=False,
                   alpha=1.0, pipeline="sync", submesh=None, pods=None,
                   use_kernel=None, depth=8, width=8, hw=8, lr=0.05,
                   compute_dtype="float32", wire_dtype=None,
                   wire_dtype_bwd=None, seed=0):
    """Train SFPL and SFLv2 through the unified round engine on the same
    data, fleet size, and placement; return accuracy under BOTH test
    protocols (IID and non-IID batches) per scheme, so the head-to-head
    comparison is not confounded by the evaluation protocol. Each scheme
    is evaluated with the BN treatment it trained with (SFPL: CMSD,
    batch statistics; SFLv2: RMSD, aggregated running statistics).
    ``compute_dtype="bfloat16"`` runs both schemes on the mixed-precision
    ``ComputePolicy`` path (f32 master params and BN statistics);
    ``wire_dtype`` / ``wire_dtype_bwd`` narrow the sharded SFPL
    exchange's on-wire dtype (``core.wire`` — SFLv2 has no collector
    exchange, so the knob only affects the SFPL side of the
    comparison)."""
    from repro.core import engine as E
    from repro.core.evaluate import evaluate_split_iid, evaluate_split_noniid
    from repro.data import make_synthetic_cifar, partition_positive_labels
    from repro.launch.train import make_compute_policy
    from repro.models import resnet as R
    from repro.optim import sgd_momentum

    cfg = R.ResNetConfig(depth=depth, num_classes=num_clients, width=width)
    key = jax.random.PRNGKey(seed)
    tx, ty, ex, ey = make_synthetic_cifar(
        key, num_classes=num_clients, train_per_class=4 * batch_size,
        test_per_class=2 * batch_size, hw=hw)
    data = partition_positive_labels(tx, ty, num_clients)
    split = E.make_resnet_split(cfg, policy=make_compute_policy(
        compute_dtype, use_kernel, wire_dtype, wire_dtype_bwd))
    opt = sgd_momentum(lr, momentum=0.9, weight_decay=5e-4)

    def run(scheme):
        st = E.init_dcml_state(jax.random.PRNGKey(seed),
                               lambda k: R.init(k, cfg), num_clients,
                               opt, opt)
        if sharded:
            from repro.core import engine_dist as ED
            shards = ED.fit_shards(num_clients, batch_size, scheme=scheme,
                                   alpha=alpha,
                                   collector_pipeline=pipeline,
                                   collector_submesh=submesh, pods=pods)
            mesh = ED.make_data_mesh(shards, pods=pods)
            if scheme == "sfpl":
                st = ED.shard_dcml_state(st, mesh)
                epoch = ED.make_sfpl_epoch_sharded(
                    split, opt, opt, ED.shard_client_data(data, mesh),
                    mesh=mesh, num_clients=num_clients,
                    batch_size=batch_size, alpha=alpha,
                    collector_pipeline=pipeline,
                    collector_submesh=submesh, use_kernel=use_kernel,
                    wire_dtype=wire_dtype, wire_dtype_bwd=wire_dtype_bwd)
            else:
                epoch = ED.make_sflv2_epoch_sharded(
                    split, opt, opt, data, mesh=mesh,
                    num_clients=num_clients, batch_size=batch_size)
        elif scheme == "sfpl":
            epoch = jax.jit(lambda k, s: E.sfpl_epoch(
                k, s, data, split, opt, opt, num_clients=num_clients,
                batch_size=batch_size, alpha=alpha))
        else:
            epoch = jax.jit(lambda k, s: E.sflv2_epoch(
                k, s, data, split, opt, opt, num_clients=num_clients,
                batch_size=batch_size))
        k = jax.random.PRNGKey(seed + 1)
        for _ in range(epochs):
            k, ke = jax.random.split(k)
            st, _ = epoch(ke, st)
        rmsd = scheme == "sflv2"
        return {
            "iid": evaluate_split_iid(st, split, ex, ey, num_clients,
                                      rmsd=rmsd, batch=2 * batch_size),
            "noniid": evaluate_split_noniid(st, split, ex, ey,
                                            num_clients, rmsd=rmsd,
                                            batch=2 * batch_size),
        }

    return {"sfpl": run("sfpl"), "sflv2": run("sflv2"),
            "num_clients": num_clients, "sharded": sharded}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--ckpt")
    ap.add_argument("--paper", action="store_true",
                    help="SFPL vs SFLv2 at matched fleet size")
    ap.add_argument("--sharded", action="store_true",
                    help="run both schemes on a mesh (with --paper)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--pipeline", default="sync",
                    choices=("sync", "double_buffered"),
                    help="sharded SFPL collector pipeline (with --paper "
                         "--sharded)")
    ap.add_argument("--submesh", dest="submesh", action="store_true",
                    default=None,
                    help="force sub-mesh streaming on (default: auto when "
                         "the balanced grouped layout qualifies)")
    ap.add_argument("--pods", type=int, default=None,
                    help="split the sharded mesh into this many pods (the "
                         "2-D ('pod', 'data') multi-host topology)")
    ap.add_argument("--no-submesh", dest="submesh", action="store_false",
                    help="force the whole-mesh streaming fallback")
    ap.add_argument("--use-kernel", dest="use_kernel", action="store_true",
                    default=None,
                    help="force the Pallas collector bucket kernels on "
                         "(default: auto — on when the backend is TPU)")
    ap.add_argument("--no-kernel", dest="use_kernel", action="store_false",
                    help="force the Pallas collector bucket kernels off")
    ap.add_argument("--compute-dtype", dest="compute_dtype",
                    default="float32", choices=("float32", "bfloat16"),
                    help="paper mode: split-model compute dtype (bfloat16 "
                         "= mixed precision with f32 master params)")
    from repro.core.wire import WIRE_DTYPE_NAMES
    ap.add_argument("--wire-dtype", dest="wire_dtype", default=None,
                    choices=WIRE_DTYPE_NAMES,
                    help="sharded SFPL: on-wire dtype of the smashed-data "
                         "exchange (int8/float8_e4m3 quantize per row; "
                         "default: ship rows as computed)")
    ap.add_argument("--wire-dtype-bwd", dest="wire_dtype_bwd", default=None,
                    choices=WIRE_DTYPE_NAMES,
                    help="sharded SFPL: wire dtype of the routed-back "
                         "gradient rows (default: exact)")
    ap.add_argument("--compilation-cache-dir", dest="compilation_cache_dir",
                    default=None,
                    help="persist XLA compilations to this directory "
                         "(jax_compilation_cache_dir) so repeat launches "
                         "skip recompiles")
    args = ap.parse_args()
    if args.compilation_cache_dir:
        jax.config.update("jax_compilation_cache_dir",
                          args.compilation_cache_dir)
    if args.paper:
        rep = evaluate_paper(num_clients=args.clients, epochs=args.epochs,
                             sharded=args.sharded, alpha=args.alpha,
                             pipeline=args.pipeline, submesh=args.submesh,
                             pods=args.pods, use_kernel=args.use_kernel,
                             compute_dtype=args.compute_dtype,
                             wire_dtype=args.wire_dtype,
                             wire_dtype_bwd=args.wire_dtype_bwd)
        chance = 100.0 / args.clients
        print(f"matched fleet ({args.clients} clients, "
              f"sharded={args.sharded}, chance {chance:.1f}%):")
        for scheme in ("sfpl", "sflv2"):
            r = rep[scheme]
            print(f"  {scheme:5s}  IID test {r['iid']['accuracy']:5.1f}%  "
                  f"non-IID test {r['noniid']['accuracy']:5.1f}%")
        return
    spec = get_arch(args.arch)
    cfg = spec.make_smoke_config()
    params = spec.model.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        from repro.checkpoint import restore_checkpoint
        params, step = restore_checkpoint(args.ckpt, params)
        print(f"restored step {step}")
    m = evaluate_lm(spec, cfg, params, batches=args.batches)
    print(f"{args.arch}: loss {m['loss']:.4f}  ppl {m['ppl']:.1f}  "
          f"token-acc {m['token_accuracy']:.3f}")


if __name__ == "__main__":
    main()
