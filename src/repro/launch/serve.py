"""Serving driver: batched prefill + incremental decode with KV cache /
recurrent state (runnable on CPU with smoke configs).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b \
      --batch 4 --prompt-len 16 --gen 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch.steps import make_decode_step


def serve(arch_id, *, batch=4, prompt_len=16, gen=16, smoke=True,
          temperature=0.0, seed=0):
    spec = get_arch(arch_id)
    cfg = (spec.make_smoke_config() if smoke else spec.make_config())
    model = spec.model
    key = jax.random.PRNGKey(seed)
    params = model.init(key, cfg)
    max_len = prompt_len + gen

    key, kt = jax.random.split(key)
    prompts = jax.random.randint(kt, (batch, prompt_len), 0,
                                 cfg.vocab_size)

    if spec.family == "xlstm":
        state = model.init_decode_state(cfg, batch)
    elif spec.family == "whisper":
        frames = jax.random.normal(key, (batch, 8, cfg.d_model))
        enc = model.encode(params, frames, cfg, training=False)
        state = model.init_decode_state(cfg, batch, max_len,
                                        dtype=jnp.float32, enc_frames=8)
        state = model.prefill_cross(params, enc, state, cfg)
    else:
        state = model.init_decode_state(cfg, batch, max_len,
                                        dtype=jnp.float32)

    decode = jax.jit(make_decode_step(spec, cfg))

    # prefill token-by-token (teacher forcing through the cache) then sample
    t0 = time.time()
    toks = prompts[:, :1]
    out_tokens = [prompts]
    logits = None
    for t in range(max_len - 1):
        cur = (prompts[:, t:t + 1] if t < prompt_len
               else out_tokens[-1])
        logits, state = decode(params, state, cur, jnp.int32(t))
        if t >= prompt_len - 1:
            if temperature > 0:
                key, ks = jax.random.split(key)
                nxt = jax.random.categorical(
                    ks, logits[:, -1] / temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out_tokens.append(nxt.astype(jnp.int32))
    gen_toks = jnp.concatenate(out_tokens[1:], axis=1)
    dt = time.time() - t0
    tps = batch * (max_len - prompt_len) / dt
    print(f"{arch_id}: decoded {gen_toks.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s on CPU smoke config)")
    return gen_toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          gen=args.gen, temperature=args.temperature)


if __name__ == "__main__":
    main()
