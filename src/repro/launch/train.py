"""Training driver.

Two modes:
  * LM mode (default): train an assigned-architecture smoke config on the
    synthetic Markov token stream — runnable on CPU, demonstrates the full
    step (optimizer, schedule, checkpointing) and the SFPL collector option
    (``--sfpl`` inserts the cut-layer shuffle into the jitted step).
  * Paper mode (``--paper``): a DCML round engine on the synthetic
    CIFAR-like set with a split ResNet. ``--scheme`` picks SFPL (default)
    or the SFLv2 baseline; ``--sharded`` runs the same round body on a
    ("data",) mesh across all visible devices (SFPL: clients + pooled
    smashed batch sharded, collector as an explicit all_to_all in
    ``--collector {balanced,uniform}`` mode with flush threshold
    ``--alpha``; SFLv2: the server stream sharded over the batch axis).
    ``--pipeline double_buffered`` streams the collector: each flush
    group's exchange overlaps the next group's client forward (see
    docs/collector_modes.md); ``--submesh`` / ``--no-submesh`` force the
    streamed sub-mesh routing on/off (default: auto when the balanced
    grouped layout qualifies). The exchange's local bucket gathers run
    through the Pallas collector kernels automatically on TPU
    (``--use-kernel`` / ``--no-kernel`` force the choice). To simulate a
    mesh on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count=8
    before launching.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 [--sfpl] [--ckpt out.npz]
  PYTHONPATH=src python -m repro.launch.train --paper --sharded \
      --clients 8 --epochs 4 [--scheme sflv2] [--alpha 0.5] \
      [--collector uniform] [--pipeline double_buffered] [--submesh] \
      [--use-kernel] [--wire-dtype int8] [--compilation-cache-dir .xla] \
      [--ckpt state.npz --ckpt-every 1] [--resume state.npz] \
      [--drop-rate 0.2 --straggler-rate 0.1 --straggler-timeout 0.5]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import synthetic_token_stream
from repro.launch.steps import make_train_step
from repro.optim import sgd_momentum, adamw, cosine_lr
from repro.checkpoint import save_checkpoint


def train_lm(arch_id, *, steps=50, batch=8, seq=64, smoke=True, sfpl=False,
             lr=3e-3, optimizer="adamw", ckpt=None, log_every=10):
    spec = get_arch(arch_id)
    cfg = (spec.make_smoke_config() if smoke else spec.make_config())
    model = spec.model
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)

    opt = (adamw(cosine_lr(lr, steps)) if optimizer == "adamw"
           else sgd_momentum(cosine_lr(lr, steps), momentum=0.9))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(spec, cfg, opt, sfpl=sfpl))

    vocab = cfg.vocab_size
    step = jnp.zeros((), jnp.int32)
    t0 = time.time()
    losses = []
    for i in range(steps):
        key, kd, kp = jax.random.split(key, 3)
        toks, labels = synthetic_token_stream(kd, batch=batch, seq_len=seq,
                                              vocab=vocab)
        batch_in = {"tokens": toks, "labels": labels}
        if spec.family == "whisper":
            batch_in["frame_embeds"] = jax.random.normal(
                kd, (batch, 16, cfg.d_model), jnp.float32)
        if getattr(cfg, "vision_tokens", 0):
            batch_in["vision_embeds"] = jax.random.normal(
                kd, (batch, cfg.vision_tokens, cfg.d_model))
        if sfpl:
            batch_in["perm"] = jax.random.permutation(kp, batch)
        params, opt_state, step, loss = step_fn(params, opt_state, step,
                                                batch_in)
        losses.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if ckpt:
        save_checkpoint(ckpt, params, step=int(step))
        print(f"saved checkpoint to {ckpt}")
    return losses


def make_compute_policy(compute_dtype, use_kernel=None, wire_dtype=None,
                        wire_dtype_bwd=None):
    """``ComputePolicy`` for the launchers' ``--compute-dtype`` /
    ``--wire-dtype`` knobs, or ``None`` at the all-default configuration
    (f32 compute, identity wire — which keeps the original unfused graph
    bit-for-bit, the parity baseline). A narrow wire at f32 compute is a
    valid policy on its own: the model computes in f32 and only the
    exchange payload narrows. Off-TPU the fused kernels run in interpret
    mode when forced on."""
    from repro.core.wire import resolve_wire_dtype
    wire = resolve_wire_dtype(wire_dtype)
    wire_bwd = resolve_wire_dtype(wire_dtype_bwd)
    mixed = compute_dtype is not None and compute_dtype != "float32"
    if not mixed and wire is None and wire_bwd is None:
        return None
    from repro.models.common import ComputePolicy
    return ComputePolicy(compute_dtype=compute_dtype or "float32",
                         use_fused_kernels=use_kernel,
                         kernel_interpret=jax.default_backend() != "tpu",
                         wire_dtype=wire, wire_dtype_bwd=wire_bwd)


def train_paper(*, num_clients=8, epochs=4, batch_size=8, sharded=False,
                use_kernel=None, depth=8, width=8, hw=8, lr=0.05,
                scheme="sfpl", alpha=1.0, collector="balanced",
                pipeline="sync", submesh=None, pods=None,
                compute_dtype="float32", wire_dtype=None,
                wire_dtype_bwd=None, log_every=1,
                ckpt=None, ckpt_every=0, resume=None,
                straggler_timeout=None, drop_rate=0.0, straggler_rate=0.0,
                straggler_delay=1.0, fault_seed=0):
    """DCML rounds on synthetic CIFAR, one client per class (only positive
    labels). ``scheme`` picks SFPL (Algorithm 1 + 2) or the SFLv2 baseline;
    ``sharded`` runs the same round body on a mesh over all visible devices
    (SFPL: clients + pooled batch sharded, collector as all_to_all in
    ``collector`` mode with flush threshold ``alpha``; SFLv2: the server
    stream sharded over the batch axis, visitation order preserved).
    ``compute_dtype="bfloat16"`` switches the split model onto the
    mixed-precision ``ComputePolicy`` path: f32 master params and BN
    stats, bf16 compute and smashed-data exchange, fused Pallas epilogues
    on TPU. ``wire_dtype`` (sharded SFPL) narrows the exchange payload
    independently of the compute dtype — int8/fp8 wires quantize per row
    right before each collective (``core.wire``); ``wire_dtype_bwd``
    does the same for the routed-back gradient rows. ``pods`` splits the sharded SFPL mesh into the 2-D
    ``("pod", "data")`` multi-host topology (one pod per host process
    under ``launch.multihost.initialize``; also works single-process for
    schedule parity testing).

    Fault tolerance (SFPL only): ``drop_rate`` / ``straggler_rate`` drive
    a deterministic :class:`~repro.core.faults.FaultPlan` whose per-epoch
    participation mask is threaded into the round — absent clients'
    activations are masked out of pooling/BN/loss and their local state is
    frozen for the epoch. ``straggler_timeout=None`` WAITS for stragglers
    (the host stalls); a finite timeout DROPS-AND-MASKS them. A draw that
    would empty a flush group has its lowest-index client revived (logged).
    ``ckpt`` + ``ckpt_every`` snapshot the full training state (params,
    optimizer, BN stats, PRNG key, epoch) every N epochs; ``resume``
    restores such a snapshot and continues bit-compatibly — on a sharded
    mesh only process 0 writes, but every process calls the (collective)
    save."""
    from repro.core import engine as E
    from repro.core.evaluate import evaluate_split_noniid
    from repro.core.faults import FaultPlan, ensure_group_survivor
    from repro.data import make_synthetic_cifar, partition_positive_labels
    from repro.models import resnet as R
    from repro.optim import sgd_momentum
    from repro import checkpoint as CK

    plan = None
    if drop_rate or straggler_rate:
        if scheme != "sfpl":
            raise ValueError("elastic participation (drop/straggler rates) "
                             "requires --scheme sfpl")
        plan = FaultPlan(num_clients, seed=fault_seed, drop_rate=drop_rate,
                         straggler_rate=straggler_rate,
                         straggler_delay=straggler_delay)

    cfg = R.ResNetConfig(depth=depth, num_classes=num_clients, width=width)
    key = jax.random.PRNGKey(0)
    tx, ty, ex, ey = make_synthetic_cifar(
        key, num_classes=num_clients, train_per_class=4 * batch_size,
        test_per_class=2 * batch_size, hw=hw)
    data = partition_positive_labels(tx, ty, num_clients)
    split = E.make_resnet_split(cfg, policy=make_compute_policy(
        compute_dtype, use_kernel, wire_dtype, wire_dtype_bwd))
    opt = sgd_momentum(lr, momentum=0.9, weight_decay=5e-4)
    st = E.init_dcml_state(key, lambda k: R.init(k, cfg), num_clients,
                           opt, opt)

    start_ep = 0
    key = jax.random.PRNGKey(1)
    if resume:
        st, key, start_ep = CK.restore_train_state(resume, st, key_ref=key)
        print(f"resumed from {resume} at epoch {start_ep}")

    if sharded:
        from repro.core import engine_dist as ED
        n_dev = len(jax.devices())
        if scheme == "sflv2":
            shards = ED.fit_shards(num_clients, batch_size, scheme="sflv2")
            mesh = ED.make_data_mesh(shards)
            print(f"sharded SFLv2: server stream over a {shards}-way mesh "
                  f"({n_dev} device(s)), sequential visitation preserved")
            epoch = ED.make_sflv2_epoch_sharded(
                split, opt, opt, data, mesh=mesh, num_clients=num_clients,
                batch_size=batch_size)
        else:
            shards = ED.fit_shards(num_clients, batch_size, alpha=alpha,
                                   collector_mode=collector,
                                   collector_pipeline=pipeline,
                                   collector_submesh=submesh, pods=pods,
                                   wire_dtype=wire_dtype,
                                   wire_dtype_bwd=wire_dtype_bwd)
            mesh = ED.make_data_mesh(shards, pods=pods)
            print(f"sharded SFPL: {shards}-way data mesh over {n_dev} "
                  f"device(s), collector={collector}, alpha={alpha}, "
                  f"pipeline={pipeline}, submesh={submesh}, pods={pods}, "
                  f"use_kernel={use_kernel}, compute_dtype={compute_dtype}, "
                  f"wire_dtype={wire_dtype}, wire_dtype_bwd={wire_dtype_bwd}")
            data_dev = ED.shard_client_data(data, mesh)
            st = ED.shard_dcml_state(st, mesh)
            epoch = ED.make_sfpl_epoch_sharded(
                split, opt, opt, data_dev, mesh=mesh,
                num_clients=num_clients, batch_size=batch_size,
                use_kernel=use_kernel, alpha=alpha,
                collector_mode=collector, collector_pipeline=pipeline,
                collector_submesh=submesh, wire_dtype=wire_dtype,
                wire_dtype_bwd=wire_dtype_bwd)
    elif scheme == "sflv2":
        epoch = jax.jit(lambda k, s: E.sflv2_epoch(
            k, s, data, split, opt, opt, num_clients=num_clients,
            batch_size=batch_size))
    else:
        dense = jax.jit(lambda k, s: E.sfpl_epoch(
            k, s, data, split, opt, opt, num_clients=num_clients,
            batch_size=batch_size, alpha=alpha))
        masked = jax.jit(lambda k, s, m: E.sfpl_epoch(
            k, s, data, split, opt, opt, num_clients=num_clients,
            batch_size=batch_size, alpha=alpha, participation=m))

        def epoch(k, s, participation=None):
            if participation is None:
                return dense(k, s)
            return masked(k, s, jnp.asarray(participation))

    t0 = time.time()
    mean_losses = []
    for ep in range(start_ep, epochs):
        mask = None
        if plan is not None:
            mask, wait = plan.participation(
                ep, straggler_timeout=straggler_timeout)
            mask, revived = ensure_group_survivor(mask, num_clients,
                                                  alpha=alpha)
            if revived:
                print(f"epoch {ep:3d} revived clients {revived} (their "
                      f"flush group would have no survivor)", flush=True)
            print(f"epoch {ep:3d} participation {int(mask.sum())}/"
                  f"{num_clients} (straggler wait {wait:.2f}s)", flush=True)
            if wait:
                time.sleep(wait)
        key, ke = jax.random.split(key)
        if mask is None:
            st, losses = epoch(ke, st)
        else:
            st, losses = epoch(ke, st, participation=mask)
        mean_losses.append(float(losses.mean()))
        if ep % log_every == 0 or ep == epochs - 1:
            print(f"epoch {ep:3d} loss {mean_losses[-1]:.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if ckpt and ckpt_every and (ep + 1) % ckpt_every == 0:
            CK.save_train_state(ckpt, st, key=key, epoch=ep + 1)
            print(f"epoch {ep:3d} checkpoint -> {ckpt}", flush=True)
    if ckpt:
        CK.save_train_state(ckpt, st, key=key, epoch=epochs)
        print(f"saved final training state to {ckpt}")
    rep = evaluate_split_noniid(st, split, ex, ey, num_clients, rmsd=False,
                                batch=2 * batch_size)
    print(f"non-IID accuracy {rep['accuracy']:.1f}% "
          f"(chance {100.0 / num_clients:.1f}%)")
    return mean_losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--sfpl", action="store_true")
    ap.add_argument("--lr", type=float, default=None,
                    help="default 3e-3 (LM mode) / 0.05 (--paper)")
    ap.add_argument("--optimizer", default="adamw",
                    help="LM mode only; --paper is SGD-momentum (paper)")
    ap.add_argument("--ckpt")
    ap.add_argument("--paper", action="store_true",
                    help="SFPL round engine on synthetic CIFAR")
    ap.add_argument("--sharded", action="store_true",
                    help="mesh-sharded engine (with --paper)")
    ap.add_argument("--use-kernel", dest="use_kernel", action="store_true",
                    default=None,
                    help="force the Pallas collector bucket kernels on "
                         "(default: auto — on when the backend is TPU)")
    ap.add_argument("--no-kernel", dest="use_kernel", action="store_false",
                    help="force the Pallas collector bucket kernels off")
    ap.add_argument("--scheme", default="sfpl", choices=("sfpl", "sflv2"),
                    help="paper mode: DCML scheme to run")
    ap.add_argument("--alpha", type=float, default=1.0,
                    help="SFPL collector accumulation threshold")
    ap.add_argument("--collector", default="balanced",
                    choices=("balanced", "uniform"),
                    help="sharded SFPL collector permutation mode")
    ap.add_argument("--pipeline", default="sync",
                    choices=("sync", "double_buffered"),
                    help="sharded SFPL collector pipeline: sync (one "
                         "blocking exchange) or double_buffered (per-"
                         "flush-group exchange overlapping the next "
                         "group's client forward)")
    ap.add_argument("--submesh", dest="submesh", action="store_true",
                    default=None,
                    help="force sub-mesh streaming on: each flush group's "
                         "exchange is a dense zero-slack collective over "
                         "its owning shard slice (default: auto — on when "
                         "the balanced grouped layout qualifies)")
    ap.add_argument("--pods", type=int, default=None,
                    help="split the sharded SFPL mesh into this many pods "
                         "(the 2-D ('pod', 'data') multi-host topology; "
                         "default: single-pod 1-D mesh)")
    ap.add_argument("--no-submesh", dest="submesh", action="store_false",
                    help="force the whole-mesh streaming fallback")
    ap.add_argument("--compute-dtype", dest="compute_dtype",
                    default="float32", choices=("float32", "bfloat16"),
                    help="paper mode: split-model compute dtype — bfloat16 "
                         "keeps f32 master params/BN stats/loss but runs "
                         "convs, BN+ReLU epilogues, and the smashed-data "
                         "exchange in bf16 (half the collector payload)")
    from repro.core.wire import WIRE_DTYPE_NAMES
    ap.add_argument("--wire-dtype", dest="wire_dtype", default=None,
                    choices=WIRE_DTYPE_NAMES,
                    help="sharded SFPL: on-wire dtype of the smashed-data "
                         "exchange, independent of --compute-dtype — "
                         "int8/float8_e4m3 quantize per row (f32 scales "
                         "ride the same collective); default: ship rows "
                         "as computed")
    ap.add_argument("--wire-dtype-bwd", dest="wire_dtype_bwd", default=None,
                    choices=WIRE_DTYPE_NAMES,
                    help="sharded SFPL: wire dtype of the routed-back "
                         "gradient rows (default: exact — the backward "
                         "leg is the more quantization-sensitive one)")
    ap.add_argument("--compilation-cache-dir", dest="compilation_cache_dir",
                    default=None,
                    help="persist XLA compilations to this directory "
                         "(jax_compilation_cache_dir) so repeat launches "
                         "skip recompiles")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--ckpt-every", dest="ckpt_every", type=int, default=0,
                    help="paper mode: save the full training state "
                         "(params, optimizer, BN stats, PRNG key, epoch) "
                         "to --ckpt every N epochs (0: final only)")
    ap.add_argument("--resume",
                    help="paper mode: restore a --ckpt training-state "
                         "snapshot and continue from its epoch")
    ap.add_argument("--straggler-timeout", dest="straggler_timeout",
                    type=float, default=None,
                    help="straggler policy: None waits for stragglers, a "
                         "finite timeout drops-and-masks clients slower "
                         "than it")
    ap.add_argument("--drop-rate", dest="drop_rate", type=float,
                    default=0.0,
                    help="per-(epoch, client) dropout probability "
                         "(deterministic FaultPlan; absent clients are "
                         "masked out of the round)")
    ap.add_argument("--straggler-rate", dest="straggler_rate", type=float,
                    default=0.0,
                    help="per-(epoch, client) straggler probability")
    ap.add_argument("--straggler-delay", dest="straggler_delay", type=float,
                    default=1.0,
                    help="seconds a straggler lags (see "
                         "--straggler-timeout)")
    ap.add_argument("--fault-seed", dest="fault_seed", type=int, default=0,
                    help="FaultPlan seed — the whole fault schedule is a "
                         "pure function of (seed, epoch)")
    args = ap.parse_args()
    if args.compilation_cache_dir:
        jax.config.update("jax_compilation_cache_dir",
                          args.compilation_cache_dir)
    if args.paper:
        losses = train_paper(num_clients=args.clients, epochs=args.epochs,
                             batch_size=args.batch, sharded=args.sharded,
                             use_kernel=args.use_kernel,
                             scheme=args.scheme, alpha=args.alpha,
                             collector=args.collector,
                             pipeline=args.pipeline, submesh=args.submesh,
                             pods=args.pods,
                             compute_dtype=args.compute_dtype,
                             wire_dtype=args.wire_dtype,
                             wire_dtype_bwd=args.wire_dtype_bwd,
                             lr=args.lr if args.lr is not None else 0.05,
                             ckpt=args.ckpt, ckpt_every=args.ckpt_every,
                             resume=args.resume,
                             straggler_timeout=args.straggler_timeout,
                             drop_rate=args.drop_rate,
                             straggler_rate=args.straggler_rate,
                             straggler_delay=args.straggler_delay,
                             fault_seed=args.fault_seed)
    else:
        losses = train_lm(args.arch, steps=args.steps, batch=args.batch,
                          seq=args.seq, smoke=args.smoke, sfpl=args.sfpl,
                          lr=args.lr if args.lr is not None else 3e-3,
                          optimizer=args.optimizer, ckpt=args.ckpt)
    if losses:
        print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
