"""Training driver.

Two modes:
  * LM mode (default): train an assigned-architecture smoke config on the
    synthetic Markov token stream — runnable on CPU, demonstrates the full
    step (optimizer, schedule, checkpointing) and the SFPL collector option
    (``--sfpl`` inserts the cut-layer shuffle into the jitted step).
  * Paper mode (``--paper``): the SFPL/SFLv2/FL round engines on the
    synthetic CIFAR-like set with ResNet-8/32/56 (see examples/ and
    benchmarks/ for the full study).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 [--sfpl] [--ckpt out.npz]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import synthetic_token_stream
from repro.launch.steps import make_train_step
from repro.optim import sgd_momentum, adamw, cosine_lr
from repro.checkpoint import save_checkpoint


def train_lm(arch_id, *, steps=50, batch=8, seq=64, smoke=True, sfpl=False,
             lr=3e-3, optimizer="adamw", ckpt=None, log_every=10):
    spec = get_arch(arch_id)
    cfg = (spec.make_smoke_config() if smoke else spec.make_config())
    model = spec.model
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)

    opt = (adamw(cosine_lr(lr, steps)) if optimizer == "adamw"
           else sgd_momentum(cosine_lr(lr, steps), momentum=0.9))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(spec, cfg, opt, sfpl=sfpl))

    vocab = cfg.vocab_size
    step = jnp.zeros((), jnp.int32)
    t0 = time.time()
    losses = []
    for i in range(steps):
        key, kd, kp = jax.random.split(key, 3)
        toks, labels = synthetic_token_stream(kd, batch=batch, seq_len=seq,
                                              vocab=vocab)
        batch_in = {"tokens": toks, "labels": labels}
        if spec.family == "whisper":
            batch_in["frame_embeds"] = jax.random.normal(
                kd, (batch, 16, cfg.d_model), jnp.float32)
        if getattr(cfg, "vision_tokens", 0):
            batch_in["vision_embeds"] = jax.random.normal(
                kd, (batch, cfg.vision_tokens, cfg.d_model))
        if sfpl:
            batch_in["perm"] = jax.random.permutation(kp, batch)
        params, opt_state, step, loss = step_fn(params, opt_state, step,
                                                batch_in)
        losses.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
    if ckpt:
        save_checkpoint(ckpt, params, step=int(step))
        print(f"saved checkpoint to {ckpt}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--sfpl", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt")
    args = ap.parse_args()
    losses = train_lm(args.arch, steps=args.steps, batch=args.batch,
                      seq=args.seq, smoke=args.smoke, sfpl=args.sfpl,
                      lr=args.lr, optimizer=args.optimizer, ckpt=args.ckpt)
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
