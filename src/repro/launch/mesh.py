"""Production mesh builders (functions, not module-level constants, so that
importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    The multi-pod mesh carries the same ``("pod", "data")`` leading axes
    as the CPU-testable collector mesh (``engine_dist.make_data_mesh(...,
    pods=...)`` / ``launch.multihost.make_pod_mesh``): the collector
    shards the pooled batch over ``collector_axis(mesh)`` — the pod-major
    name tuple — so an epoch validated on the multi-process CPU harness
    (tests/test_multihost.py) runs the identical collective schedule
    here."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for smoke tests / examples on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))
