import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) and dump
# memory/cost/collective analysis. The XLA_FLAGS line above MUST execute
# before any jax import (jax locks the device count on first init).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
#       --shape train_4k [--multi-pod] [--sfpl] [--out results.json]
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out-dir dryrun_out

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs, input_specs, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_train_step, make_prefill_step, make_decode_step)
from repro.optim import sgd_momentum
from repro.sharding import param_shardings, batch_shardings, state_shardings
from repro.roofline.hlo import collective_bytes_from_text


def _named(mesh, spec=None):
    from jax.sharding import PartitionSpec as P
    return jax.sharding.NamedSharding(mesh, spec or P())


def lower_one(arch_id, shape_name, *, multi_pod=False, sfpl=False,
              optimizer="sgdm", cfg_overrides=None, keep_text=False,
              fsdp=True):
    """Returns a result dict with memory/cost analysis + collective bytes."""
    spec = get_arch(arch_id)
    shape = SHAPES[shape_name]
    skip = spec.skip_reason(shape)
    if skip:
        return {"arch": arch_id, "shape": shape_name, "skipped": skip}

    mesh_axes = (("pod", 2), ("data", 16), ("model", 16)) if multi_pod \
        else (("data", 16), ("model", 16))
    overrides = dict(cfg_overrides or {})
    overrides.setdefault("mesh_axes", mesh_axes)
    cfg = spec.make_config(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = spec.model
    t0 = time.time()

    params_sds = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), cfg))
    p_shard = param_shardings(params_sds, mesh, fsdp=fsdp)
    specs = input_specs(spec, cfg, shape)

    with mesh:
        if shape.kind == "train":
            opt = (sgd_momentum(1e-2, momentum=0.9,
                                state_dtype=jnp.float32)
                   if optimizer == "sgdm" else None)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            opt_shard = jax.tree_util.tree_map(
                lambda _: None, opt_sds,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            opt_shard = {"mu": p_shard}
            batch = dict(specs)
            if sfpl:
                batch["perm"] = jax.ShapeDtypeStruct(
                    (shape.global_batch,), jnp.int32)
            b_shard = batch_shardings(specs, mesh)
            if sfpl:
                b_shard["perm"] = _named(mesh)
            step_fn = make_train_step(spec, cfg,
                                      opt, sfpl=sfpl)
            step_sds = jax.ShapeDtypeStruct((), jnp.int32)
            jf = jax.jit(step_fn,
                         in_shardings=(p_shard, opt_shard, _named(mesh),
                                       b_shard),
                         donate_argnums=(0, 1))
            lowered = jf.lower(params_sds, opt_sds, step_sds, batch)
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(spec, cfg)
            b_shard = batch_shardings(specs, mesh)
            jf = jax.jit(step_fn, in_shardings=(p_shard, b_shard))
            lowered = jf.lower(params_sds, specs)
        else:  # decode
            step_fn = make_decode_step(spec, cfg)
            state_sds = specs["state"]
            s_shard = state_shardings(state_sds, mesh)
            tok_sds = specs["tokens"]
            t_shard = batch_shardings({"tokens": tok_sds}, mesh)["tokens"]
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            jf = jax.jit(step_fn,
                         in_shardings=(p_shard, s_shard, t_shard,
                                       _named(mesh)),
                         donate_argnums=(1,))
            lowered = jf.lower(params_sds, state_sds, tok_sds, pos_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    coll = collective_bytes_from_text(text)

    n_dev = mesh.devices.size
    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "sfpl": sfpl,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                  None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "collectives": coll,
    }
    if keep_text:
        result["hlo_text"] = text
    return result


def summarize(res):
    if "skipped" in res:
        return f"{res['arch']:28s} {res['shape']:12s} SKIP: {res['skipped'][:50]}"
    m = res["memory"]
    per_dev = (m["argument_bytes"] or 0) + (m["temp_bytes"] or 0) \
        - (m.get("alias_bytes") or 0)
    return (f"{res['arch']:28s} {res['shape']:12s} {res['mesh']:8s} "
            f"args+temp-alias={per_dev/2**30:7.2f}GiB/dev "
            f"flops={res['cost']['flops'] or 0:.3e} "
            f"coll={sum(v['bytes'] for v in res['collectives'].values())/2**30:.2f}GiB "
            f"compile={res['compile_s']:.0f}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sfpl", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--out-dir")
    args = ap.parse_args()

    jobs = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                jobs.append((a, s, args.multi_pod))
    else:
        jobs.append((args.arch, args.shape, args.multi_pod))

    results = []
    for arch_id, shape_name, mp in jobs:
        try:
            res = lower_one(arch_id, shape_name, multi_pod=mp,
                            sfpl=args.sfpl)
        except Exception as e:   # record failures, keep sweeping
            res = {"arch": arch_id, "shape": shape_name,
                   "mesh": "2x16x16" if mp else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"FAIL {arch_id} {shape_name}: {e}", flush=True)
        results.append(res)
        if "error" not in res:
            print(summarize(res), flush=True)
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            fn = f"{arch_id}_{shape_name}_{res.get('mesh','NA')}.json"
            with open(os.path.join(args.out_dir, fn), "w") as f:
                json.dump(res, f, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
