"""Step functions (train / prefill / decode) shared by dryrun, train, serve."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_train_step(spec, cfg, optimizer, *, sfpl=False, cut_groups=1):
    """Returns train_step(params, opt_state, step, batch[, perm]).

    With ``sfpl=True`` (transformer family) the batch dict must contain
    "perm" — the global-collector permutation; the smashed data is shuffled
    at the cut layer inside the step (all-to-all over the data axis) and the
    gradient de-shuffle is the VJP of that gather.
    """
    model = spec.model

    def loss_of(params, batch):
        if sfpl and spec.family == "transformer":
            from repro.core.split_lm import sfpl_lm_loss
            return sfpl_lm_loss(model, params, batch, cfg,
                                perm=batch["perm"], cut_groups=cut_groups)
        clean = {k: v for k, v in batch.items() if k != "perm"}
        return model.loss_fn(params, clean, cfg, training=True)

    def train_step(params, opt_state, step, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_of(p, batch), has_aux=True)(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params,
                                               step)
        return new_params, new_opt, step + 1, loss

    return train_step


def make_prefill_step(spec, cfg):
    model = spec.model

    def prefill_step(params, batch):
        # serving prefill: only the final position's logits are needed to
        # seed decode; returning (B, S, V) logits would dominate memory.
        logits, _ = model.forward(params, batch, cfg, training=False,
                                  last_token_only=True)
        return logits

    return prefill_step


def make_decode_step(spec, cfg):
    model = spec.model

    def decode_step(params, state, tokens, cur_pos):
        return model.decode_step(params, state, tokens, cfg,
                                 cur_pos=cur_pos)

    return decode_step
