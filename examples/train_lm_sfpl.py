"""End-to-end LM training driver with SFPL as a first-class feature:
a ~100M-parameter qwen3-family model trained for a few hundred steps on the
synthetic Markov stream, with the global-collector shuffle inside the jitted
train step (--sfpl) — the production integration of the paper's technique.

Run:  PYTHONPATH=src python examples/train_lm_sfpl.py --steps 300 --sfpl
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import synthetic_token_stream
from repro.launch.steps import make_train_step
from repro.models.common import count_params
from repro.optim import adamw, cosine_lr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sfpl", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    spec = get_arch("qwen3-8b")
    # ~100M-parameter member of the qwen3 family
    cfg = spec.make_config(num_layers=8, d_model=512, num_heads=8,
                           num_kv_heads=4, head_dim=64, d_ff=1536,
                           vocab_size=32000, remat=False)
    params = spec.model.init(jax.random.PRNGKey(0), cfg)
    print(f"model: {count_params(params) / 1e6:.1f}M params, "
          f"sfpl={'ON' if args.sfpl else 'off'}")

    opt = adamw(cosine_lr(args.lr, args.steps, warmup=20))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(spec, cfg, opt, sfpl=args.sfpl))

    key = jax.random.PRNGKey(1)
    step = jnp.zeros((), jnp.int32)
    t0 = time.time()
    first = None
    for i in range(args.steps):
        key, kd, kp = jax.random.split(key, 3)
        toks, labels = synthetic_token_stream(
            kd, batch=args.batch, seq_len=args.seq, vocab=cfg.vocab_size)
        batch = {"tokens": toks, "labels": labels}
        if args.sfpl:
            batch["perm"] = jax.random.permutation(kp, args.batch)
        params, opt_state, step, loss = step_fn(params, opt_state, step,
                                                batch)
        if first is None:
            first = float(loss)
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"({tok_s:.0f} tok/s)", flush=True)
    print(f"\nloss {first:.3f} -> {float(loss):.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
