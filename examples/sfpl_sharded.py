"""Mesh-sharded SFPL: Algorithm 1 with the collector as an all_to_all.

Eight host devices stand in for an accelerator mesh. Eight clients (one
class each — only positive labels) and the pooled smashed-data batch are
sharded over a ("data",) mesh; every server-side update shuffles the pool
with one explicit all_to_all (balanced block permutation, drop-free by
construction) and the activation-gradient de-shuffle is the same exchange
with the inverse permutation, supplied by autodiff. The run finishes by
checking the loss trajectory against the single-device engine — including
a partial-flush round (``alpha=0.5``: per-flush-group balanced exchanges
aligned to shard boundaries), the paper-faithful uniform collector mode
with auto-sized slack, the double-buffered streaming pipeline
(per-group issue/complete exchanges overlapping the next group's client
forward), and sub-mesh streaming (each flush group's all_to_all scoped
to the shard slice owning its rows, with dense zero-slack plans). A
final pair of legs folds the same devices into a 2-D ("pod", "data")
multi-host layout and repeats the sync and pod-local sub-mesh checks.

With ``--compute-dtype bfloat16`` the whole run repeats on the
mixed-precision ``ComputePolicy`` path (f32 master params, bf16 client
forward and smashed exchange, f32 BN statistics and loss); the
single-vs-sharded trajectory tolerance loosens from the f32 1e-4 to the
documented bf16 1e-2 — the sharded and dense engines see identically
rounded activations, the residual delta is exchange-order rounding.

Run:  PYTHONPATH=src python examples/sfpl_sharded.py \
          [--compute-dtype {float32,bfloat16}]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--compute-dtype", dest="compute_dtype",
                    default="float32", choices=("float32", "bfloat16"))
    args = ap.parse_args()
    tol = 1e-4 if args.compute_dtype == "float32" else 1e-2

    V = 8                   # clients == classes == mesh shards
    cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
    key = jax.random.PRNGKey(0)
    tx, ty, ex, ey = make_synthetic_cifar(
        key, num_classes=V, train_per_class=32, test_per_class=16, hw=8)
    data = partition_positive_labels(tx, ty, V)
    from repro.launch.train import make_compute_policy
    split = E.make_resnet_split(
        cfg, policy=make_compute_policy(args.compute_dtype, None))
    print(f"compute_dtype={args.compute_dtype} (tolerance {tol:g})")
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st0 = E.init_dcml_state(key, lambda k: R.init(k, cfg), V, opt, opt)
    st0_host = jax.tree_util.tree_map(np.asarray, st0)

    mesh = ED.make_data_mesh(8)
    print(f"mesh: {mesh.devices.shape} over axis {mesh.axis_names}")
    data_sh = ED.shard_client_data(data, mesh)
    epoch = ED.make_sfpl_epoch_sharded(
        split, opt, opt, data_sh, mesh=mesh, num_clients=V, batch_size=8,
        check_capacity=True)

    st = ED.shard_dcml_state(st0, mesh)
    key = jax.random.PRNGKey(1)
    keys, sh_losses = [], []
    for ep in range(4):
        key, ke = jax.random.split(key)
        keys.append(ke)
        st, losses = epoch(ke, st)      # donated: buffers reused in place
        sh_losses.append(np.asarray(losses))
        print(f"epoch {ep} sharded loss {float(losses.mean()):.4f}")

    from repro.core.evaluate import evaluate_split_noniid
    rep = evaluate_split_noniid(st, split, ex, ey, V, rmsd=False, batch=16)
    print(f"non-IID accuracy {rep['accuracy']:.1f}% (chance 12.5%)")

    # single-device engine on the same seeds: trajectories must agree
    ref_step = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8))
    st_ref = jax.tree_util.tree_map(jnp.asarray, st0_host)
    ref_losses = []
    for ke in keys:
        st_ref, losses = ref_step(ke, st_ref)
        ref_losses.append(np.asarray(losses))
    diff = np.abs(np.concatenate(ref_losses)
                  - np.concatenate(sh_losses)).max()
    print(f"max |single - sharded| loss delta: {diff:.2e} "
          f"(tolerance {tol:g})")
    assert diff < tol

    # partial collector flushes on the mesh: alpha=0.5 pools two 4-client
    # groups per flush; the grouped balanced exchange must track the
    # single-device flush-group shuffle
    for mode_kw, label in (
            ({"alpha": 0.5}, "alpha=0.5"),
            ({"collector_mode": "uniform"}, "uniform"),
            # double-buffered streaming: each flush group's all_to_all is
            # issued while the next group's client forward computes, and
            # the final in-flight group is drained after the loop — the
            # trajectory still tracks the single-device oracle
            ({"alpha": 0.5, "collector_pipeline": "double_buffered"},
             "alpha=0.5 streamed"),
            # sub-mesh streaming, required rather than auto-detected:
            # each 32-row flush group's all_to_all runs only over its
            # own 4-shard slice, with slice-local DENSE plans (exact
            # capacity, zero slack padding)
            ({"alpha": 0.5, "collector_pipeline": "double_buffered",
              "collector_submesh": True},
             "alpha=0.5 sub-mesh streamed")):
        ep_m = ED.make_sfpl_epoch_sharded(
            split, opt, opt, data_sh, mesh=mesh, num_clients=V,
            batch_size=8, check_capacity=True, **mode_kw)
        ref_m = jax.jit(lambda k, s: E.sfpl_epoch(
            k, s, data, split, opt, opt, num_clients=V, batch_size=8,
            alpha=mode_kw.get("alpha", 1.0)))
        _, l_m = ep_m(keys[0], ED.shard_dcml_state(
            jax.tree_util.tree_map(jnp.asarray, st0_host), mesh))
        _, l_r = ref_m(keys[0], jax.tree_util.tree_map(jnp.asarray,
                                                       st0_host))
        d = float(np.abs(np.asarray(l_m) - np.asarray(l_r)).max())
        print(f"{label} collector loss delta: {d:.2e}")
        assert d < tol

    # pod mesh: the same 8 devices folded into a 2-D ("pod", "data")
    # multi-host layout (2 pods x 4 shards — single-process here; see
    # tests/test_multihost.py for real process boundaries). Every route
    # plan works unchanged over the pod-major flattened shard index, and
    # pod-local flush groups keep their dense sub-mesh exchanges.
    pod_mesh = ED.make_data_mesh(8, pods=2)
    print(f"pod mesh: {pod_mesh.devices.shape} over axis "
          f"{pod_mesh.axis_names}")
    pod_data = ED.shard_client_data(data, pod_mesh)
    for mode_kw, label in (
            ({}, "pod sync"),
            # alpha=0.5 spans two 32-row groups of 4 shards each — exactly
            # the per-pod width, so sub-mesh routing stays pod-local
            ({"alpha": 0.5, "collector_pipeline": "double_buffered",
              "collector_submesh": True},
             "pod alpha=0.5 sub-mesh streamed")):
        ep_m = ED.make_sfpl_epoch_sharded(
            split, opt, opt, pod_data, mesh=pod_mesh, num_clients=V,
            batch_size=8, check_capacity=True, **mode_kw)
        ref_m = jax.jit(lambda k, s: E.sfpl_epoch(
            k, s, data, split, opt, opt, num_clients=V, batch_size=8,
            alpha=mode_kw.get("alpha", 1.0)))
        _, l_m = ep_m(keys[0], ED.shard_dcml_state(
            jax.tree_util.tree_map(jnp.asarray, st0_host), pod_mesh))
        _, l_r = ref_m(keys[0], jax.tree_util.tree_map(jnp.asarray,
                                                       st0_host))
        d = float(np.abs(np.asarray(l_m) - np.asarray(l_r)).max())
        print(f"{label} collector loss delta: {d:.2e}")
        assert d < tol


if __name__ == "__main__":
    main()
