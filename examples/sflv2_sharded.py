"""Mesh-sharded SFLv2: the baseline's server stream at fleet scale.

SFLv2 visits clients SEQUENTIALLY in random order — the catastrophic-
forgetting mechanism the paper studies — so the visitation loop must not
be parallelized. What CAN scale is the server-side update stream: this
example shards the per-client batch axis over a ("data",) mesh (eight
host devices standing in for accelerators), so every server forward/
backward runs data-parallel while the visitation order stays bit-for-bit
identical to the single-device engine. The run finishes by checking the
loss trajectory and the server params against ``engine.sflv2_epoch``.

(The parity check runs a short horizon deliberately: the sharded batch
reduces BN statistics and gradients in a different float order, and
SFLv2's sequential single-class stream amplifies that ~1e-7 noise
geometrically — ~x3 per server update — so long chains drift apart even
though step one is bit-identical. SFPL has no such chain; its parity
holds at any horizon.)

With both SFPL and SFLv2 running on the same mesh from the same round
body (``repro.core.round``), the paper's scheme comparison happens at
matched fleet sizes.

Run:  PYTHONPATH=src python examples/sflv2_sharded.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum


def main():
    V = 8                   # clients == classes (only positive labels)
    cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
    key = jax.random.PRNGKey(0)
    tx, ty, ex, ey = make_synthetic_cifar(
        key, num_classes=V, train_per_class=16, test_per_class=16, hw=8)
    data = partition_positive_labels(tx, ty, V)
    split = E.make_resnet_split(cfg)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st0 = E.init_dcml_state(key, lambda k: R.init(k, cfg), V, opt, opt)
    st0_host = jax.tree_util.tree_map(np.asarray, st0)

    mesh = ED.make_data_mesh(8)
    print(f"mesh: {mesh.devices.shape} over axis {mesh.axis_names}")
    epoch = ED.make_sflv2_epoch_sharded(
        split, opt, opt, data, mesh=mesh, num_clients=V, batch_size=8)

    st = jax.tree_util.tree_map(jnp.asarray, st0_host)
    key = jax.random.PRNGKey(1)
    keys, sh_losses = [], []
    for ep in range(2):
        key, ke = jax.random.split(key)
        keys.append(ke)
        st, losses = epoch(ke, st)
        sh_losses.append(np.asarray(losses))
        print(f"epoch {ep} sharded SFLv2 loss {float(losses.mean()):.4f}")

    from repro.core.evaluate import evaluate_split_iid
    rep = evaluate_split_iid(st, split, ex, ey, V, rmsd=True, batch=16)
    print(f"IID accuracy {rep['accuracy']:.1f}% (chance 12.5% — the "
          f"positive-label collapse under study)")

    # single-device engine on the same seeds: visitation order, losses and
    # server params must agree
    ref_step = jax.jit(lambda k, s: E.sflv2_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8))
    st_ref = jax.tree_util.tree_map(jnp.asarray, st0_host)
    ref_losses = []
    for ke in keys:
        st_ref, losses = ref_step(ke, st_ref)
        ref_losses.append(np.asarray(losses))
    diff = np.abs(np.concatenate(ref_losses)
                  - np.concatenate(sh_losses)).max()
    print(f"max |single - sharded| loss delta: {diff:.2e} (tolerance 1e-4)")
    assert diff < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(st_ref["sp"]),
                    jax.tree_util.tree_leaves(st["sp"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
    print("server-params parity OK")


if __name__ == "__main__":
    main()
