"""Quickstart: one SFPL round end-to-end in ~a minute on CPU.

Ten IoT clients, each holding ONLY ONE class (positive labels); a ResNet-8
split after its first conv block; the global collector shuffles the pooled
smashed data before every server-side update; ClientFedServer averages the
client models excluding BatchNorm.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import engine as E
from repro.core.evaluate import evaluate_split_noniid
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum


def main():
    V = 4                       # classes == clients
    cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
    key = jax.random.PRNGKey(0)

    tx, ty, ex, ey = make_synthetic_cifar(
        key, num_classes=V, train_per_class=48, test_per_class=24, hw=16)
    data = partition_positive_labels(tx, ty, V)
    print(f"{V} clients, each holding exactly one class: "
          f"{data['x'].shape[1]} samples each")

    split = E.make_resnet_split(cfg)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st = E.init_dcml_state(key, lambda k: R.init(k, cfg), V, opt, opt)

    epoch = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8,
        bn_mode="cmsd"))

    for ep in range(6):
        key, ke = jax.random.split(key)
        st, losses = epoch(ke, st)
        print(f"epoch {ep}: mean server loss {float(losses.mean()):.4f}")

    rep = evaluate_split_noniid(st, split, ex, ey, V, rmsd=False)
    print(f"\nSFPL non-IID test: accuracy {rep['accuracy']:.1f}% "
          f"(chance = {100 / V:.0f}%), precision@1 {rep['precision@1']:.3f}")


if __name__ == "__main__":
    main()
