"""The paper's headline experiment, end to end: SFLv2 collapses under
positive-only labels while SFPL recovers (Tables I & V), including the
CMSD/RMSD comparison (Tables VI-VIII).

Run:  PYTHONPATH=src:. python examples/sfpl_vs_sflv2.py [--epochs 10]
"""
import argparse
import sys

sys.path.insert(0, ".")

from benchmarks.common import setup, run_scheme  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--depth", type=int, default=8, choices=(8, 32, 56))
    args = ap.parse_args()

    env = setup(num_classes=args.classes, depth=args.depth)
    print(f"ResNet-{args.depth}, {args.classes} single-class clients, "
          f"{args.epochs} epochs\n")

    print("== SFLv2 (baseline under study) ==")
    _, rep, dt, _ = run_scheme(env, "sflv2", epochs=args.epochs,
                               bn_mode="rmsd")
    acc_sfl = rep(testing_iid=True)["accuracy"]
    print(f"  non-IID training -> IID test accuracy: {acc_sfl:.1f}% "
          f"(chance {100 / args.classes:.0f}%)  [{dt:.1f}s/epoch]")

    print("== SFPL (this paper) ==")
    _, rep, dt, _ = run_scheme(env, "sfpl", epochs=args.epochs,
                               bn_mode="cmsd")
    acc_cmsd = rep(testing_iid=False)["accuracy"]
    print(f"  CMSD, non-IID test accuracy: {acc_cmsd:.1f}%  "
          f"[{dt:.1f}s/epoch]")
    _, rep, dt, _ = run_scheme(env, "sfpl", epochs=args.epochs,
                               bn_mode="rmsd")
    acc_rmsd_iid = rep(testing_iid=True)["accuracy"]
    print(f"  RMSD, IID test accuracy:     {acc_rmsd_iid:.1f}%")

    print(f"\nimprovement factor (SFPL/SFLv2): "
          f"{acc_cmsd / max(acc_sfl, 1e-9):.2f}x "
          f"(paper reports 8.5-51.5x at CIFAR scale)")


if __name__ == "__main__":
    main()
