"""Batched serving example: prefill + incremental decode with KV cache /
recurrent state across three architecture families (dense GQA, MoE with
sliding-window ring cache, and an attention-free recurrent model).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import serve


def main():
    for arch in ("qwen3-8b", "llama4-scout-17b-a16e", "xlstm-1.3b"):
        serve(arch, batch=4, prompt_len=12, gen=12, temperature=0.8)


if __name__ == "__main__":
    main()
