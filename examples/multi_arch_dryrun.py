"""Lower + compile a few (architecture x input-shape) pairs against the
production 16x16 mesh and print their memory/cost analysis — a miniature of
launch/dryrun.py --all.

Run:  PYTHONPATH=src python examples/multi_arch_dryrun.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import lower_one, summarize  # noqa: E402


def main():
    pairs = [
        ("qwen3-8b", "train_4k"),
        ("recurrentgemma-9b", "long_500k"),
        ("llama4-scout-17b-a16e", "decode_32k"),
    ]
    for arch, shape in pairs:
        res = lower_one(arch, shape)
        print(summarize(res))
        for op, d in res["collectives"].items():
            print(f"    {op:20s} count={d['count']:4d} "
                  f"traffic={d['traffic_bytes'] / 2 ** 30:.3f} GiB/dev")


if __name__ == "__main__":
    main()
