"""Continuous-batching scheduler: batched greedy decode must equal
sequential single-request decode, across mixed prompt lengths and slot
recycling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.serving import ContinuousBatcher, Request


def _single_greedy(spec, cfg, params, prompt, max_new, max_len=64):
    model = spec.model
    state = model.init_decode_state(cfg, 1, max_len, dtype=jnp.float32)
    toks = list(prompt)
    out = []
    t = 0
    cur = prompt[0]
    while len(out) < max_new:
        lg, state = model.decode_step(
            params, state, jnp.asarray([[cur]], jnp.int32), cfg, cur_pos=t)
        t += 1
        if t < len(prompt):
            cur = prompt[t]
            continue
        cur = int(jnp.argmax(lg[0, -1]))
        out.append(cur)
    return out


def test_continuous_batching_matches_sequential():
    spec = get_arch("qwen3-8b")
    cfg = spec.make_smoke_config(compute_dtype="float32",
                                 param_dtype="float32")
    params = spec.model.init(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    prompts = [
        jax.random.randint(jax.random.fold_in(key, i), (n,), 0,
                           cfg.vocab_size).tolist()
        for i, n in enumerate((3, 7, 5, 4, 6))]

    batcher = ContinuousBatcher(spec, cfg, params, num_slots=2, max_len=64)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    for r in reqs:
        batcher.submit(r)
    done, ticks = batcher.run()
    assert len(done) == 5
    # 5 requests through 2 slots forces recycling
    assert ticks > 0

    for r in reqs:
        ref = _single_greedy(spec, cfg, params, r.prompt, 6)
        assert r.output == ref, (r.prompt, r.output, ref)


def test_scheduler_slot_reuse_isolated():
    """A recycled slot must not leak KV entries from its previous tenant."""
    spec = get_arch("qwen3-8b")
    cfg = spec.make_smoke_config(compute_dtype="float32",
                                 param_dtype="float32")
    params = spec.model.init(jax.random.PRNGKey(0), cfg)
    prompt = [5, 9, 2]
    # run the same prompt as first and as third request on 1 slot
    batcher = ContinuousBatcher(spec, cfg, params, num_slots=1, max_len=64)
    for p in (prompt, [1, 2, 3, 4], prompt):
        batcher.submit(Request(prompt=list(p), max_new_tokens=5))
    done, _ = batcher.run()
    assert done[0].output == done[2].output
