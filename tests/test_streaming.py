"""Streaming (double-buffered) collector: the pipelined epoch must track
the synchronous parity oracle, and the drain epilogue must never drop the
final in-flight flush group.

Trajectory parity runs in a subprocess with 8 forced host devices (the
device count must be fixed before jax initializes); the drain property
tests run in-process on a 1-shard mesh, where issue/complete and the
two-slot pipeline are exercised end to end without a device farm.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

WORKER_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

V = 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)
mesh = ED.make_data_mesh(8)
data_sh = ED.shard_client_data(data, mesh)

def fresh():
    return ED.shard_dcml_state(
        jax.tree_util.tree_map(jnp.asarray, st0_host), mesh)

keys = list(jax.random.split(jax.random.PRNGKey(1), 2))

# sync (the blocking parity oracle) vs double_buffered trajectories for
# both flush structures and both collector permutation modes: the streamed
# pipeline re-orders dataflow, never values, so the loss trajectories must
# agree to 1e-5 (they are bit-identical in practice)
for alpha in (0.25, 1.0):
    for mode in ("balanced", "uniform"):
        mk = lambda pipe: ED.make_sfpl_epoch_sharded(
            split, opt, opt, data_sh, mesh=mesh, num_clients=V,
            batch_size=8, alpha=alpha, collector_mode=mode,
            collector_pipeline=pipe)
        e_sync, e_db = mk("sync"), mk("double_buffered")
        st_a, st_b, deltas = fresh(), fresh(), []
        for ke in keys:
            st_a, l_a = e_sync(ke, st_a)
            st_b, l_b = e_db(ke, st_b)
            deltas.append(float(np.abs(np.asarray(l_a)
                                       - np.asarray(l_b)).max()))
        d = max(deltas)
        assert d <= 1e-5, (alpha, mode, d)
        # FedAvg'd client params must agree too (full round-trip through
        # the explicit route_back de-shuffle)
        for a, b in zip(jax.tree_util.tree_leaves(st_a["cp"]),
                        jax.tree_util.tree_leaves(st_b["cp"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        print(f"stream-parity OK alpha={alpha} mode={mode} ({d:.2e})")
print("all-stream-parity OK")
"""


@pytest.mark.parametrize("_", [0])
def test_double_buffered_matches_sync(_, tmp_path):
    """sync vs double_buffered loss trajectories and FedAvg'd params for
    alpha in {0.25, 1.0} x {balanced, uniform} at 8 forced host devices."""
    script = tmp_path / "worker_stream.py"
    script.write_text(WORKER_PARITY)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "all-stream-parity OK" in res.stdout, res.stdout


def _one_shard_strategy(num_clients, alpha, mode):
    from repro.core import round as RD
    mesh = jax.make_mesh((1,), ("data",))
    return RD.StreamingAllToAll(mesh=mesh, num_clients=num_clients,
                                alpha=alpha, mode=mode)


@settings(deadline=None, max_examples=10)
@given(num_clients=st.sampled_from([2, 4, 8]),
       alpha=st.sampled_from([0.25, 0.5, 1.0]),
       batch=st.sampled_from([2, 4]),
       mode=st.sampled_from(["balanced", "uniform"]))
def test_drain_never_drops_final_group(num_clients, alpha, batch, mode):
    """Property: the two-slot pipeline's drain epilogue reproduces
    ``pool[perm]`` EXACTLY — in particular the final in-flight flush
    group's rows all land (every pool value is strictly positive, so any
    dropped row would surface as a zero)."""
    from repro.core.round import streamed_shuffle
    coll = _one_shard_strategy(num_clients, alpha, mode)
    n = num_clients * batch
    key = jax.random.PRNGKey(n + int(alpha * 100))
    x = jax.random.uniform(key, (n, 3), minval=0.5, maxval=1.5)
    perm = coll.make_perm(jax.random.fold_in(key, 1), n)
    bounds = coll.group_bounds(n)
    out = jax.jit(lambda x, p: streamed_shuffle(
        coll, p, n, lambda g: x[bounds[g][0]:bounds[g][1]]))(x, perm)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(x)[np.asarray(perm)])
    # the drained (final) group specifically: bit-exact, nothing zeroed
    r0, r1 = bounds[-1]
    last = np.asarray(out)[r0:r1]
    assert (last > 0).all(), "drain epilogue dropped rows of final group"


def test_issue_complete_composition_matches_shuffle():
    """exchange_complete(exchange_issue(x, perm)) == shuffle_shard_map
    (x, perm) == x[perm], and the streamed route_back inverts it."""
    from repro.core.collector_dist import (exchange_complete,
                                           exchange_issue,
                                           shuffle_shard_map)
    mesh = jax.make_mesh((1,), ("data",))
    n = 24
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (n, 4))
    perm = jax.random.permutation(jax.random.fold_in(key, 1), n)

    @jax.jit
    def go(x, perm):
        slot = exchange_issue(x, perm, mesh=mesh, slack=1.0)
        return exchange_complete(slot, n, mesh=mesh)
    out = go(x, perm)
    ref = shuffle_shard_map(x, perm, mesh=mesh, slack=1.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(x)[np.asarray(perm)])

    coll = _one_shard_strategy(num_clients=4, alpha=1.0, mode="uniform")
    back = jax.jit(lambda g, p: coll.route_back(g, p, n))(out, perm)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_streaming_layout_validation():
    """double_buffered layouts whose flush groups do not divide over the
    shards are rejected eagerly with an actionable ValueError."""
    from repro.core.engine_dist import check_sfpl_layout
    assert check_sfpl_layout(
        8, 8, 8, alpha=0.25,
        collector_pipeline="double_buffered") == [16] * 4
    # 2-client groups * 2 rows = 4 rows, not divisible by 8 shards
    with pytest.raises(ValueError, match="double_buffered"):
        check_sfpl_layout(8, 2, 8, alpha=0.25, collector_mode="uniform",
                          collector_pipeline="double_buffered")
