"""Elastic-participation differential matrix on 8 forced CPU devices.

One subprocess (fresh XLA_FLAGS before jax import) runs a masked epoch —
clients [2, 6] absent, both alpha=0.5 flush groups keep survivors —
through every collector strategy:

  * DenseTake      — the unsharded single-device engine,
  * MeshAllToAll   — the sync sharded collector on an 8-way mesh,
  * StreamingAllToAll — double_buffered, sub-mesh and whole-mesh fallback,

x alpha {0.5, 1.0}, and pins loss AND post-epoch state (client leaves at
surviving indices, full server leaves) within 1e-5 of an ORACLE epoch
run over only the surviving clients (shared broadcast init makes the
restriction exact — absence must be indistinguishable from never having
enrolled). A second worker proves full-state resume is BIT-compatible on
the sharded mesh: save after epoch 0, restore into a fresh process-alike
state, run epoch 1, and demand max|diff| == 0 against the uninterrupted
run (same devices, same schedule — nothing may drift).
"""
import os
import subprocess
import sys

WORKER_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

V, B = 8, 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
tx, ty, _, _ = make_synthetic_cifar(jax.random.PRNGKey(0), num_classes=V,
                                    train_per_class=16, test_per_class=8,
                                    hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
init = lambda k: R.init(k, cfg)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), init, V, opt, opt)
host = jax.tree_util.tree_map(np.asarray, st0)
fresh = lambda: jax.tree_util.tree_map(jnp.asarray, host)
ke = jax.random.PRNGKey(1)

mask = np.array([1, 1, 0, 1, 1, 1, 0, 1], bool)
surv = np.where(mask)[0]

md = lambda a, b: max(
    float(np.abs(np.asarray(x) - np.asarray(y)).max())
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)))
take = lambda t: jax.tree_util.tree_map(lambda x: np.asarray(x)[surv], t)

# oracle: the same problem restricted to the survivors
def oracle(alpha):
    st_o = E.init_dcml_state(jax.random.PRNGKey(0), init, len(surv),
                             opt, opt)
    data_o = {k: v[surv] for k, v in data.items()}
    return jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data_o, split, opt, opt, num_clients=len(surv),
        batch_size=B, alpha=alpha))(ke, st_o)

refs = {a: oracle(a) for a in (0.5, 1.0)}

def check(name, alpha, st_m, l_m):
    st_ref, l_ref = refs[alpha]
    dl = md(l_m, l_ref)
    dc = max(md(take(st_m[k]), st_ref[k]) for k in ("cp", "cbn"))
    ds = max(md(st_m[k], st_ref[k]) for k in ("sp", "sbn"))
    assert dl < 1e-5 and dc < 1e-5 and ds < 1e-5, (name, dl, dc, ds)
    print("elastic OK", name, dl, dc, ds, flush=True)

# DenseTake (unsharded single-device engine)
for alpha in (0.5, 1.0):
    st_m, l_m = jax.jit(lambda k, s, a=alpha: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=B,
        alpha=a, participation=jnp.asarray(mask)))(ke, fresh())
    check(f"dense-a{alpha}", alpha, st_m, l_m)

# sharded collectors on the 8-way mesh
mesh = ED.make_data_mesh(8)
data_dev = ED.shard_client_data(data, mesh)
cells = [("mesh-a2a", 1.0, {}), ("mesh-a2a", 0.5, {}),
         ("stream-submesh", 0.5, dict(
             collector_pipeline="double_buffered", collector_submesh=True)),
         ("stream-fallback", 0.5, dict(
             collector_pipeline="double_buffered",
             collector_submesh=False)),
         ("stream", 1.0, dict(collector_pipeline="double_buffered"))]
for name, alpha, kw in cells:
    sts = ED.shard_dcml_state(fresh(), mesh)
    epoch = ED.make_sfpl_epoch_sharded(
        split, opt, opt, data_dev, mesh=mesh, num_clients=V,
        batch_size=B, alpha=alpha, **kw)
    sts, ls = epoch(ke, sts, participation=mask)
    check(f"{name}-a{alpha}", alpha, sts, ls)

# the validated sharded entrypoint rejects a group-emptying mask eagerly
epoch05 = ED.make_sfpl_epoch_sharded(
    split, opt, opt, data_dev, mesh=mesh, num_clients=V, batch_size=B,
    alpha=0.5)
try:
    epoch05(ke, ED.shard_dcml_state(fresh(), mesh),
            participation=np.array([1, 1, 1, 1, 0, 0, 0, 0], bool))
except ValueError as e:
    assert "flush group 1" in str(e), e
    print("elastic eager-reject OK", flush=True)
else:
    raise AssertionError("group-emptying mask was not rejected")
print("all-elastic OK")
"""

WORKER_RESUME = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum
from repro import checkpoint as CK

V, B = 8, 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
tx, ty, _, _ = make_synthetic_cifar(jax.random.PRNGKey(0), num_classes=V,
                                    train_per_class=16, test_per_class=8,
                                    hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
host = jax.tree_util.tree_map(np.asarray, st0)
fresh = lambda: jax.tree_util.tree_map(jnp.asarray, host)
mask = np.array([1, 0, 1, 1, 1, 1, 1, 0], bool)

mesh = ED.make_data_mesh(8)
data_dev = ED.shard_client_data(data, mesh)
epoch = ED.make_sfpl_epoch_sharded(split, opt, opt, data_dev, mesh=mesh,
                                   num_clients=V, batch_size=B, alpha=0.5)

def run(st, key, n, first_mask=None):
    losses = []
    for ep in range(n):
        key, ke = jax.random.split(key)
        m = first_mask if ep == 0 else None
        st, ls = (epoch(ke, st) if m is None
                  else epoch(ke, st, participation=m))
        losses.append(np.asarray(ls))
    return st, key, losses

# uninterrupted: elastic epoch 0, dense epoch 1
st_a, _, losses_a = run(ED.shard_dcml_state(fresh(), mesh),
                        jax.random.PRNGKey(1), 2, first_mask=mask)

# interrupted: epoch 0 only, full-state snapshot, then a RESTORED state
# (host reference tree -> restore -> reshard) finishes epoch 1
with tempfile.TemporaryDirectory() as tmp:
    path = os.path.join(tmp, "state.npz")
    st_b, key_b, _ = run(ED.shard_dcml_state(fresh(), mesh),
                         jax.random.PRNGKey(1), 1, first_mask=mask)
    CK.save_train_state(path, st_b, key=key_b, epoch=1)
    del st_b
    st_r, key_r, ep0 = CK.restore_train_state(path, fresh())
    assert ep0 == 1, ep0
    st_r = ED.shard_dcml_state(st_r, mesh)
    st_r, _, losses_r = run(st_r, key_r, 1)

md = lambda a, b: max(
    float(np.abs(np.asarray(x) - np.asarray(y)).max())
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)))
dl = float(np.abs(losses_r[0] - losses_a[1]).max())
ds = md(st_r, st_a)
assert dl == 0.0 and ds == 0.0, (dl, ds)
print("resume bit-compat OK", dl, ds)
"""


def _run_worker(tmp_path, code, tokens, timeout=540):
    w = tmp_path / "worker.py"
    w.write_text(code)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run([sys.executable, str(w)], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    for tok in tokens:
        assert tok in r.stdout, r.stdout + r.stderr
    return r.stdout


def test_elastic_differential_matrix(tmp_path):
    out = _run_worker(tmp_path, WORKER_ELASTIC,
                      ["elastic OK dense-a0.5", "elastic OK dense-a1.0",
                       "elastic OK mesh-a2a-a0.5",
                       "elastic OK mesh-a2a-a1.0",
                       "elastic OK stream-submesh-a0.5",
                       "elastic OK stream-fallback-a0.5",
                       "elastic OK stream-a1.0",
                       "elastic eager-reject OK", "all-elastic OK"])
    assert out.count("elastic OK ") == 7  # "all-elastic OK" not counted


def test_sharded_resume_bit_compat(tmp_path):
    _run_worker(tmp_path, WORKER_RESUME, ["resume bit-compat OK"])
