"""Differential property suite for the sub-mesh streaming exchange.

The tentpole claim: routing each flush group's exchange only over its
owning shard slice (dense plans + ``axis_index_groups``) changes the
DATAFLOW, never the values. Every test here pins the new path to an
oracle that does not share its code:

  * trajectory parity — the sub-mesh streamed sharded epoch (and the
    uniform whole-mesh streamed fallback) against the single-device
    sync dense oracle ``engine.sfpl_epoch`` (``DenseTake``: one
    ``jnp.take``, no mesh, no plans), for forward loss AND the
    client/server parameters the gradients update, across
    mode x alpha x forced 8/16 host devices;
  * a host-side numpy simulation of the grouped ``all_to_all``
    semantics replaying sub-mesh route plans over randomized
    (slice, slab, group) shapes — forward reproduces ``x[perm]`` on the
    group's rows and the backward plan inverts it, without ever
    launching a collective;
  * the streamed uniform fallback's slack probing is memoized on the
    group row counts actually used (one probe per distinct size).

Device-farm legs run in subprocesses (the forced host device count must
be set before jax initializes), mirroring tests/test_streaming.py.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

WORKER_TEMPLATE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

NDEV = %(ndev)d
V = NDEV  # one client per class, one per shard
B = %(batch)d  # slab b = B rows/shard; alpha=1.0 needs b %% NDEV == 0
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)
mesh = ED.make_data_mesh(NDEV)
data_sh = ED.shard_client_data(data, mesh)

def fresh_sharded():
    return ED.shard_dcml_state(
        jax.tree_util.tree_map(jnp.asarray, st0_host), mesh)

def fresh_single():
    return jax.tree_util.tree_map(jnp.asarray, st0_host)

keys = list(jax.random.split(jax.random.PRNGKey(1), %(nkeys)d))

# the sync dense oracle: every client on one device, the collector a
# dense jnp.take -- no mesh, no route plans, no streaming (the SFPL
# server update is permutation-invariant, so every collector mode's
# trajectory must match it)
def oracle(alpha):
    ep = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=B,
        alpha=alpha))
    s, losses = fresh_single(), []
    for ke in keys:
        s, l = ep(ke, s)
        losses.append(np.asarray(l))
    return s, np.stack(losses)

for alpha in (0.25, 0.5, 1.0):
    ref_st, ref_losses = oracle(alpha)
    # balanced + submesh=True: the dense slice-confined exchange is
    # REQUIRED (prepare raises if the layout were to disqualify);
    # uniform + submesh=None: the whole-mesh streamed fallback with
    # per-group probed slack (uniform never qualifies for sub-mesh)
    for mode, submesh in (("balanced", True), ("uniform", None)):
        ep = ED.make_sfpl_epoch_sharded(
            split, opt, opt, data_sh, mesh=mesh, num_clients=V,
            batch_size=B, alpha=alpha, collector_mode=mode,
            collector_pipeline="double_buffered", collector_submesh=submesh)
        s, losses = fresh_sharded(), []
        for ke in keys:
            s, l = ep(ke, s)
            losses.append(np.asarray(l))
        d = float(np.abs(np.stack(losses) - ref_losses).max())
        assert d <= 1e-5, (alpha, mode, d)
        # client AND server parameters after the epochs: the round-trip
        # through issue/complete, the server grad, and the explicit
        # route_back de-shuffle all feed these
        for part in ("cp", "sp"):
            for a, b in zip(jax.tree_util.tree_leaves(ref_st[part]),
                            jax.tree_util.tree_leaves(s[part])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5,
                                           err_msg=f"{alpha} {mode} {part}")
        print(f"submesh-oracle OK ndev={NDEV} alpha={alpha} mode={mode} "
              f"({d:.2e})", flush=True)
print("all-submesh-oracle OK")
"""


def _run_worker(tmp_path, ndev, nkeys, batch, timeout):
    script = tmp_path / f"worker_submesh_{ndev}.py"
    script.write_text(WORKER_TEMPLATE
                      % {"ndev": ndev, "nkeys": nkeys, "batch": batch})
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "all-submesh-oracle OK" in res.stdout, res.stdout


def test_submesh_matches_dense_oracle_8dev(tmp_path):
    """Sub-mesh streamed (balanced) and whole-mesh streamed fallback
    (uniform) trajectories vs the single-device sync dense oracle:
    loss + client/server params <= 1e-5 over alpha {0.25, 0.5, 1.0} at
    8 forced host devices."""
    _run_worker(tmp_path, ndev=8, nkeys=2, batch=8, timeout=560)


def test_submesh_matches_dense_oracle_16dev(tmp_path):
    """The same differential matrix at 16 forced host devices (16
    clients, slices of 4/8/16 shards across the alphas)."""
    _run_worker(tmp_path, ndev=16, nkeys=1, batch=16, timeout=560)


# --------------------------------------------------------------------------
# host-side simulation of the grouped collective: plans replayed in numpy


def _simulate_plan_exchange(x, plan, n_shards):
    """Replay one plan exchange with the documented ``all_to_all``
    semantics, no devices: within each ``axis_index_groups`` slice, the
    receive block ``recv[j]`` on member ``s`` is member ``j``'s send
    bucket at position ``local_rank(s)``."""
    from repro.core import collector_dist as CD
    n, d = x.shape
    b = n // n_shards
    S = plan.slice_size or n_shards
    cap = plan.cap
    send = np.asarray(plan.send_idx)
    ridx = np.asarray(plan.recv_idx)
    groups = (CD.submesh_axis_groups(n_shards, S) if plan.slice_size
              else [list(range(n_shards))])
    bucket = np.stack([x[s * b:(s + 1) * b][send[s]].reshape(S, cap, d)
                       for s in range(n_shards)])
    out = np.zeros((n_shards, b, d), x.dtype)
    for members in groups:
        for rank, s in enumerate(members):
            recv = np.stack([bucket[j, rank] for j in members])
            flat = recv.reshape(S * cap, d)
            if plan.may_drop:
                flat = np.concatenate(
                    [flat, np.zeros((1, d), x.dtype)])
            out[s] = flat[ridx[s]]
    return out.reshape(n, d)


# (n_shards, slice_size) pairs covering 1-shard slices, partial slices,
# and the whole-mesh-as-one-slice degenerate case
_SHAPES = [(4, 1), (4, 2), (4, 4), (8, 2), (8, 4), (8, 8)]


@settings(deadline=None, max_examples=12)
@given(shape=st.sampled_from(_SHAPES),
       cap=st.sampled_from([1, 2, 3]),
       seed=st.sampled_from([0, 7]))
def test_submesh_plans_reproduce_perm_on_host(shape, cap, seed):
    """Property over randomized (slice, slab, capacity) layouts: the
    embedded sub-mesh plans, replayed under host-simulated grouped
    all_to_all semantics, reproduce ``x_g[sub_perm]`` exactly on every
    group's rows, are DENSE (no pad row, no overflow), and the backward
    plan inverts the forward one. Sub-perms are drawn from
    ``make_balanced_perm`` — the dense exact-capacity contract only
    holds for balanced block permutations (exactly what the engine's
    ``make_grouped_balanced_perm`` feeds the sub-mesh path); a uniform
    draw can route 3 rows into a 2-row bucket."""
    from repro.core.collector_dist import (build_submesh_route_plans,
                                           make_balanced_perm)
    n_shards, S = shape
    b = S * cap                      # slab rows; cap = b / S exactly
    n = n_shards * b
    n_g = S * b                      # rows per flush group
    n_groups = n_shards // S
    rng = np.random.default_rng(1000 * seed + 10 * n_shards + S)
    x = rng.standard_normal((n, 3)).astype(np.float32)
    expect = np.zeros_like(x)
    back = np.zeros_like(x)
    for g in range(n_groups):
        sub_perm = np.asarray(make_balanced_perm(
            jax.random.PRNGKey(7919 * seed + 31 * g + n_shards),
            n_g, S)).astype(np.int32)
        fwd, bwd = build_submesh_route_plans(
            jax.numpy.asarray(sub_perm), g, n_shards, S)
        for plan in (fwd, bwd):
            assert plan.dense and plan.slice_size == S
            assert plan.overflow is None and not plan.may_drop
            assert plan.cap == cap
            assert plan.send_idx.shape == (n_shards, b)
            assert plan.recv_idx.shape == (n_shards, b)
        r0, r1 = g * n_g, (g + 1) * n_g
        out = _simulate_plan_exchange(x, fwd, n_shards)
        expect[r0:r1] = out[r0:r1]
        np.testing.assert_array_equal(out[r0:r1], x[r0:r1][sub_perm])
        # backward plan applied to the shuffled rows recovers the source
        y = np.zeros_like(x)
        y[r0:r1] = out[r0:r1]
        inv = _simulate_plan_exchange(y, bwd, n_shards)
        back[r0:r1] = inv[r0:r1]
    # stitched over all groups: the full grouped permutation, inverted
    np.testing.assert_array_equal(back, x)
    assert (expect != 0).any()


def test_whole_mesh_simulation_matches_jax_oracle():
    """Anchor the host simulation itself: on whole-mesh plans it must
    agree with the real ``plan_shuffle`` on a 1-shard mesh (the only
    mesh available in-process), so the sub-mesh property above is not
    tested against a broken model of the collective."""
    from repro.core.collector_dist import build_route_plans, plan_shuffle
    mesh = jax.make_mesh((1,), ("data",))
    n = 12
    rng = np.random.default_rng(5)
    x = rng.standard_normal((n, 4)).astype(np.float32)
    perm = rng.permutation(n).astype(np.int32)
    plans = build_route_plans(jax.numpy.asarray(perm), 1, cap=n,
                              may_drop=True)
    real = jax.jit(lambda x: plan_shuffle(x, plans, mesh=mesh))(x)
    sim = _simulate_plan_exchange(x, plans[0], 1)
    np.testing.assert_array_equal(np.asarray(real), sim)
    np.testing.assert_array_equal(sim, x[perm])


# --------------------------------------------------------------------------
# streamed uniform fallback: slack probing memoized on group sizes used


def test_streamed_uniform_slack_cached_per_group_size():
    """The streamed uniform fallback probes ``uniform_auto_slack`` at
    each flush group's OWN row count: one cache miss per distinct size,
    hits for every same-sized group and every re-prepare."""
    from repro.core import round as RD
    from repro.core.collector_dist import _uniform_auto_slack_cached

    mesh = jax.make_mesh((1,), ("data",))
    coll = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=0.25,
                                mode="uniform")
    n = 8 * 6
    rows = coll.group_rows(n)
    assert len(rows) == 4 and len(set(rows)) == 1  # 4 equal groups
    perm = jax.numpy.arange(n)

    _uniform_auto_slack_cached.cache_clear()
    before = _uniform_auto_slack_cached.cache_info()
    coll.prepare(perm, n)
    after = _uniform_auto_slack_cached.cache_info()
    # one probe for the single distinct group size, reused by the other
    # three same-sized groups
    assert after.misses - before.misses == 1, after
    assert after.hits - before.hits == len(rows) - 1, after

    coll.prepare(perm, n)  # re-trace / second step: all hits
    again = _uniform_auto_slack_cached.cache_info()
    assert again.misses == after.misses, again
    assert again.hits - after.hits == len(rows), again


def test_submesh_knob_validation():
    """``submesh=True`` on a non-qualifying layout raises with the
    disqualifying condition named; ``submesh=False`` forces the
    fallback; the sync pipeline rejects the knob outright."""
    from repro.core import round as RD

    mesh = jax.make_mesh((1,), ("data",))
    uni = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=0.25,
                               mode="uniform", submesh=True)
    with pytest.raises(ValueError, match="balanced"):
        uni.submesh_slices(48)
    slk = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=0.25,
                               mode="balanced", submesh=True,
                               stream_slack=2.0)
    with pytest.raises(ValueError, match="slack"):
        slk.submesh_slices(48)
    off = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=0.25,
                               mode="balanced", submesh=False)
    assert off.submesh_slices(48) is None
    auto = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=0.25,
                                mode="balanced")
    # 12-row groups inside one 48-row slab: no slice structure -> fallback
    assert auto.submesh_slices(48) is None
    one = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=1.0,
                               mode="balanced")
    assert one.submesh_slices(48) == 1  # one global flush over 1 shard
    placement = RD.DataMesh(mesh, "data")
    with pytest.raises(ValueError, match="double_buffered"):
        placement.collector(8, pipeline="sync", submesh=True)
