import jax

jax.config.update("jax_platform_name", "cpu")
# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device; only launch/dryrun.py requests 512.
