"""Decode-vs-teacher-forced-forward consistency for every decode-capable
family, and family-specific math oracles (mLSTM chunkwise == recurrent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import TransformerConfig
from repro.models import transformer as T
from repro.models import xlstm as X
from repro.models import rglru as RG
from repro.models import whisper as W


def _decode_all(model, params, state, toks, cfg):
    outs = []
    for t in range(toks.shape[1]):
        lg, state = model.decode_step(params, state, toks[:, t:t + 1], cfg,
                                      cur_pos=t)
        outs.append(lg)
    return jnp.concatenate(outs, axis=1)


def test_transformer_decode_matches_forward():
    cfg = TransformerConfig(num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=97, remat=False,
                            compute_dtype="float32",
                            param_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = T.init(key, cfg)
    toks = jax.random.randint(key, (2, 6), 0, 97)
    full, _ = T.forward(p, {"tokens": toks}, cfg, training=False)
    st = T.init_decode_state(cfg, 2, 8, dtype=jnp.float32)
    dec = _decode_all(T, p, st, toks, cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_transformer_swa_ring_cache_matches_forward():
    """Sliding-window ring cache must reproduce full-sequence SWA."""
    cfg = TransformerConfig(num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=97, sliding_window=3, remat=False,
                            compute_dtype="float32", param_dtype="float32")
    key = jax.random.PRNGKey(1)
    p = T.init(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, 97)
    full, _ = T.forward(p, {"tokens": toks}, cfg, training=False)
    st = T.init_decode_state(cfg, 2, 8, dtype=jnp.float32)  # ring slots=3
    assert st["sub0"]["k"].shape[2] == 3
    dec = _decode_all(T, p, st, toks, cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_mlstm_chunkwise_matches_recurrent_oracle():
    key = jax.random.PRNGKey(0)
    B, H, S, D = 2, 2, 12, 4
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    i_pre = jax.random.normal(ks[3], (B, H, S)) * 2
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) * 2)
    h_chunk = X._mlstm_chunk_scan(q, k, v, i_pre, logf, chunk=4)
    state = (jnp.zeros((B, H, D, D)), jnp.zeros((B, H, D)),
             jnp.full((B, H), -1e30))
    hs = []
    for t in range(S):
        state, h = X.mlstm_recurrent_step(
            state, q[:, :, t], k[:, :, t], v[:, :, t],
            i_pre[:, :, t], logf[:, :, t])
        hs.append(h)
    np.testing.assert_allclose(np.asarray(h_chunk),
                               np.asarray(jnp.stack(hs, axis=2)),
                               rtol=1e-4, atol=1e-4)


def test_xlstm_decode_matches_forward():
    cfg = X.XLSTMConfig(num_layers=4, d_model=32, num_heads=2,
                        vocab_size=53, slstm_every=2, chunk_len=4,
                        remat=False, compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = X.init(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, 53)
    full, _ = X.forward(p, {"tokens": toks}, cfg, training=False)
    st = X.init_decode_state(cfg, 2)
    outs = []
    for t in range(8):
        lg, st = X.decode_step(p, st, toks[:, t:t + 1], cfg)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_rglru_decode_matches_forward():
    cfg = RG.RGLRUConfig(num_layers=8, d_model=32, num_heads=2,
                         num_kv_heads=1, head_dim=16, d_ff=64,
                         vocab_size=53, window=4, remat=False,
                         compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = RG.init(key, cfg)
    toks = jax.random.randint(key, (2, 6), 0, 53)
    full, _ = RG.forward(p, {"tokens": toks}, cfg, training=False)
    st = RG.init_decode_state(cfg, 2, 8, dtype=jnp.float32)
    outs = []
    for t in range(6):
        lg, st = RG.decode_step(p, st, toks[:, t:t + 1], cfg, cur_pos=t)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_whisper_decode_matches_forward():
    cfg = W.WhisperConfig(num_layers=2, d_model=32, num_heads=2,
                          num_kv_heads=2, head_dim=16, d_ff=64,
                          vocab_size=53, max_source_positions=10,
                          max_target_positions=16, remat=False,
                          compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = W.init(key, cfg)
    fe = jax.random.normal(key, (2, 10, 32))
    toks = jax.random.randint(key, (2, 6), 0, 53)
    full, _ = W.forward(p, {"frame_embeds": fe, "tokens": toks}, cfg,
                        training=False)
    enc = W.encode(p, fe, cfg, training=False)
    st = W.init_decode_state(cfg, 2, 8, dtype=jnp.float32, enc_frames=10)
    st = W.prefill_cross(p, enc, st, cfg)
    outs = []
    for t in range(6):
        lg, st = W.decode_step(p, st, toks[:, t:t + 1], cfg, cur_pos=t)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_rglru_associative_scan_matches_step():
    key = jax.random.PRNGKey(4)
    W_ = 8
    p = RG.rglru_init(key, W_, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 7, W_))
    full = RG.rglru_apply(p, x)
    h = jnp.zeros((2, W_))
    outs = []
    for t in range(7):
        y, h = RG.rglru_step(p, x[:, t], h)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=1e-5, atol=1e-5)


def test_chunked_lm_loss_matches_plain():
    from repro.models.common import chunked_lm_loss, softmax_cross_entropy
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 12, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 31))
    labels = jax.random.randint(key, (2, 12), 0, 31)
    unembed = lambda xc: xc @ w
    plain = softmax_cross_entropy(unembed(x), labels)
    for chunks in (1, 2, 3, 4, 6):
        chunked = chunked_lm_loss(x, labels, unembed, chunks=chunks)
        np.testing.assert_allclose(float(chunked), float(plain), rtol=1e-6)


def test_int8_kv_cache_decode_close_to_fp32():
    """Quantized KV cache (int8 + per-slot scales): decode logits stay close
    to the fp32-cache reference (serving memory optimization)."""
    cfg = TransformerConfig(num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=97, remat=False,
                            compute_dtype="float32", param_dtype="float32")
    key = jax.random.PRNGKey(7)
    p = T.init(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, 97)
    ref_state = T.init_decode_state(cfg, 2, 8, dtype=jnp.float32)
    q_state = T.init_decode_state(cfg, 2, 8, dtype=jnp.int8)
    assert q_state["sub0"]["k"].dtype == jnp.int8
    assert "k_scale" in q_state["sub0"]
    for t in range(8):
        lr, ref_state = T.decode_step(p, ref_state, toks[:, t:t+1], cfg,
                                      cur_pos=t)
        lq, q_state = T.decode_step(p, q_state, toks[:, t:t+1], cfg,
                                    cur_pos=t)
    # int8 introduces small quantization noise; argmax ranking preserved
    ref_p = jax.nn.softmax(lr[:, -1])
    q_p = jax.nn.softmax(lq[:, -1])
    assert float(jnp.max(jnp.abs(ref_p - q_p))) < 0.05
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lr, -1)),
                                  np.asarray(jnp.argmax(lq, -1)))
