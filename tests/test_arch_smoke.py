"""Per-architecture smoke tests: reduced variant of each assigned config
(<=2-ish layers, d_model<=512, <=4 experts), one forward + one train step on
CPU, asserting output shapes and no NaNs; plus a decode step where the
architecture supports decoding."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.launch.steps import make_train_step, make_decode_step
from repro.optim import sgd_momentum

ARCHS = list_archs()


def _batch_for(spec, cfg, key, batch=2, seq=16):
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if spec.family == "whisper":
        b["frame_embeds"] = jax.random.normal(
            key, (batch, 8, cfg.d_model), jnp.float32)
    if getattr(cfg, "vision_tokens", 0):
        b["vision_embeds"] = jax.random.normal(
            key, (batch, cfg.vision_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_forward_shapes_and_finite(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config()
    assert cfg.d_model <= 512
    key = jax.random.PRNGKey(0)
    params = spec.model.init(key, cfg)
    batch = _batch_for(spec, cfg, key, batch=2, seq=16)
    logits, aux = spec.model.forward(params, batch, cfg, training=False)
    expect_seq = batch["tokens"].shape[1]
    assert logits.shape == (2, expect_seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch_id


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_train_step(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(1)
    params = spec.model.init(key, cfg)
    opt = sgd_momentum(1e-2, momentum=0.9)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(spec, cfg, opt))
    batch = _batch_for(spec, cfg, key, batch=2, seq=16)
    new_params, new_opt, step, loss = step_fn(
        params, opt_state, jnp.zeros((), jnp.int32), batch)
    assert bool(jnp.isfinite(loss)), arch_id
    assert int(step) == 1
    # params actually changed
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree_util.tree_leaves(new_params),
                               jax.tree_util.tree_leaves(params)))
    assert diff > 0.0
    finite = all(bool(jnp.isfinite(x.astype(jnp.float32)).all())
                 for x in jax.tree_util.tree_leaves(new_params))
    assert finite, arch_id


@pytest.mark.parametrize("arch_id", ARCHS)
def test_smoke_decode_step(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(2)
    params = spec.model.init(key, cfg)
    B, max_len = 2, 8
    if spec.family == "xlstm":
        state = spec.model.init_decode_state(cfg, B)
    elif spec.family == "whisper":
        frames = jax.random.normal(key, (B, 8, cfg.d_model))
        enc = spec.model.encode(params, frames, cfg, training=False)
        state = spec.model.init_decode_state(cfg, B, max_len,
                                             dtype=jnp.float32,
                                             enc_frames=8)
        state = spec.model.prefill_cross(params, enc, state, cfg)
    else:
        state = spec.model.init_decode_state(cfg, B, max_len,
                                             dtype=jnp.float32)
    decode = jax.jit(make_decode_step(spec, cfg))
    toks = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_state = decode(params, state, toks, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch_id


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    expected = {
        "minitron-8b", "qwen3-8b", "qwen2-vl-7b", "phi3-medium-14b",
        "gemma-7b", "xlstm-1.3b", "whisper-large-v3",
        "llama4-maverick-400b-a17b", "recurrentgemma-9b",
        "llama4-scout-17b-a16e"}
    assert set(ARCHS) == expected


@pytest.mark.parametrize("arch_id,params_b", [
    ("minitron-8b", 7.7), ("qwen3-8b", 8.2), ("qwen2-vl-7b", 7.6),
    ("phi3-medium-14b", 14.7), ("gemma-7b", 8.5), ("xlstm-1.3b", 1.4),
    ("whisper-large-v3", 1.5), ("llama4-maverick-400b-a17b", 400.7),
    ("recurrentgemma-9b", 9.4), ("llama4-scout-17b-a16e", 107.8)])
def test_full_config_param_counts(arch_id, params_b):
    """Full-size configs match their model cards (checked via eval_shape —
    no allocation)."""
    import math
    spec = get_arch(arch_id)
    cfg = spec.make_config()
    shapes = jax.eval_shape(
        lambda: spec.model.init(jax.random.PRNGKey(0), cfg))
    n = sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(shapes))
    assert abs(n / 1e9 - params_b) / params_b < 0.03, n / 1e9
