"""Multi-host ("pod", "data") collector mesh.

Four layers, cheapest first:

  1. in-process unit coverage of the pod plumbing — ``collector_axis``
     resolution, tuple-axis ``mesh_axis_size``, pod validation in
     ``make_data_mesh`` / ``check_sfpl_layout``, and the
     ``StreamingAllToAll`` pod-locality gate (a sub-mesh slice straddling
     pods must fall back to the whole-mesh exchange, LOGGED, and
     ``submesh=True`` must raise — never a silent drop);
  2. an in-process (1, 1) pod-mesh epoch pinned to the dense oracle — the
     tuple-axis code path (``P(("pod", "data"))`` placement, tuple-axis
     ``all_to_all``) without any subprocess;
  3. single-process subprocesses with 8 forced devices: the (2, 4) pod
     mesh differential (isolates 2-D-mesh bugs from distributed-runtime
     bugs) and the jaxpr proof that the pod axis adds NO collectives —
     per-cell all_to_all counts identical between the (8,) and (2, 4)
     meshes, zero sorts on the exchange path;
  4. the tentpole: tests/_multihost.py spawns 2 REAL coordinated JAX
     processes x 4 forced CPU devices (gloo collectives) and pins the
     sharded epoch's losses AND post-epoch client/server param trees
     (the integral of every routed-back gradient) within 1e-5 of the
     single-device oracle across {sync, double_buffered fallback,
     sub-mesh} x alpha {0.5, 1.0}, on BOTH processes.
"""
import os
import subprocess
import sys
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine_dist as ED
from repro.core import round as RD
from repro.core.collector_dist import axis_tuple, mesh_axis_size


def _fake_mesh(shape, names=("pod", "data")):
    return SimpleNamespace(axis_names=names, devices=np.zeros(shape))


# --------------------------------------------------------------------------
# 1. in-process pod plumbing


def test_collector_axis_resolution():
    pod = _fake_mesh((2, 4))
    flat = _fake_mesh((8,), names=("data",))
    assert ED.collector_axis(pod) == ("pod", "data")
    assert ED.collector_axis(flat) == "data"
    assert mesh_axis_size(pod, ("pod", "data")) == 8
    assert mesh_axis_size(pod, "data") == 4
    assert mesh_axis_size(pod, "pod") == 2
    assert axis_tuple("data") == ("data",)
    assert axis_tuple(("pod", "data")) == ("pod", "data")


def test_make_data_mesh_pod_validation():
    for pods in (3, 0, -1):
        with pytest.raises(ValueError, match="divide num_shards"):
            ED.make_data_mesh(8, pods=pods)


def test_layout_check_pod_validation():
    with pytest.raises(ValueError, match="divide n_shards"):
        ED.check_sfpl_layout(8, 8, 8, pods=3)
    # alpha=0.5 over 8 shards -> two groups spanning 4 shards each; with 4
    # pods the 4-shard slice straddles the 2-shard pods, so demanding
    # sub-mesh routing must raise eagerly...
    with pytest.raises(ValueError, match="pod-local"):
        ED.check_sfpl_layout(8, 8, 8, alpha=0.5, pods=4,
                             collector_submesh=True,
                             collector_pipeline="double_buffered")
    # ...but the layout itself stays valid: the streamed exchange falls
    # back to the probed-slack whole-mesh path
    assert ED.check_sfpl_layout(
        8, 8, 8, alpha=0.5, pods=4,
        collector_pipeline="double_buffered") == [32, 32]
    # pod-local slice (4 shards per pod, slice of 4) qualifies
    assert ED.check_sfpl_layout(
        8, 8, 8, alpha=0.5, pods=2, collector_submesh=True,
        collector_pipeline="double_buffered") == [32, 32]
    # whole-mesh slice (one global flush) qualifies on any pod split
    assert ED.check_sfpl_layout(
        8, 8, 8, alpha=1.0, pods=4, collector_submesh=True,
        collector_pipeline="double_buffered") == [64]


def test_fit_shards_honours_pods():
    assert ED.fit_shards(8, 8, pods=2, max_shards=8) == 8
    # pods=3: the 3- and 6-shard candidates fail the client divisibility
    # check, so the fallback is one shard per pod — never an unbuildable
    # mesh
    assert ED.fit_shards(8, 8, pods=3, max_shards=8) == 3
    assert ED.fit_shards(7, 3, pods=2, max_shards=8) == 2


def test_submesh_slices_pod_locality_gate(caplog):
    # (4, 2) mesh: 8 shards, 2 per pod. alpha=0.5 -> slice of 4 shards
    # straddles pods: auto mode falls back with a logged warning...
    coll = RD.StreamingAllToAll(mesh=_fake_mesh((4, 2)), num_clients=8,
                                axis=("pod", "data"), alpha=0.5)
    with caplog.at_level("WARNING", logger="repro.core.round"):
        assert coll.submesh_slices(64) is None
    assert any("straddles the pod boundary" in r.getMessage()
               for r in caplog.records)
    # ...and submesh=True raises, naming the pod boundary
    strict = RD.StreamingAllToAll(mesh=_fake_mesh((4, 2)), num_clients=8,
                                  axis=("pod", "data"), alpha=0.5,
                                  submesh=True)
    with pytest.raises(ValueError, match="straddles the pod boundary"):
        strict.submesh_slices(64)
    # pod-local slice (slice 4 == shards per pod) stays sub-mesh routed
    local = RD.StreamingAllToAll(mesh=_fake_mesh((2, 4)), num_clients=8,
                                 axis=("pod", "data"), alpha=0.5)
    assert local.submesh_slices(64) == 4
    # one global flush is the whole mesh on any pod split
    whole = RD.StreamingAllToAll(mesh=_fake_mesh((4, 2)), num_clients=8,
                                 axis=("pod", "data"), alpha=1.0)
    assert whole.submesh_slices(64) == 8


# --------------------------------------------------------------------------
# 2. in-process (1, 1) pod-mesh differential (tuple-axis path, no
# subprocess)


def _tiny_problem(num_clients=4, batch_size=4):
    from repro.core import engine as E
    from repro.data import make_synthetic_cifar, partition_positive_labels
    from repro.models import resnet as R
    from repro.optim import sgd_momentum
    cfg = R.ResNetConfig(depth=8, num_classes=num_clients, width=8)
    tx, ty, _, _ = make_synthetic_cifar(
        jax.random.PRNGKey(0), num_classes=num_clients,
        train_per_class=2 * batch_size, test_per_class=batch_size, hw=8)
    data = partition_positive_labels(tx, ty, num_clients)
    split = E.make_resnet_split(cfg)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st0 = E.init_dcml_state(jax.random.PRNGKey(0),
                            lambda k: R.init(k, cfg), num_clients, opt, opt)
    host = jax.tree_util.tree_map(np.asarray, st0)
    fresh = lambda: jax.tree_util.tree_map(jnp.asarray, host)
    return E, data, split, opt, fresh


def _tree_maxdiff(a, b, fetch=np.asarray):
    return max(float(np.abs(fetch(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def test_pod_mesh_single_device_differential():
    V = B = 4
    E, data, split, opt, fresh = _tiny_problem(V, B)
    ke = jax.random.PRNGKey(1)
    st_ref, l_ref = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V,
        batch_size=B))(ke, fresh())

    mesh = ED.make_data_mesh(1, pods=1)
    assert ED.collector_axis(mesh) == ("pod", "data")
    sts = ED.shard_dcml_state(fresh(), mesh)
    epoch = ED.make_sfpl_epoch_sharded(
        split, opt, opt, ED.shard_client_data(data, mesh), mesh=mesh,
        num_clients=V, batch_size=B)
    sts, ls = epoch(ke, sts)
    assert float(np.abs(np.asarray(ls) - np.asarray(l_ref)).max()) < 1e-5
    assert _tree_maxdiff(sts["cp"], st_ref["cp"]) < 1e-5
    assert _tree_maxdiff(sts["sp"], st_ref["sp"]) < 1e-5


# --------------------------------------------------------------------------
# 3. single-process subprocesses: (2, 4) differential + jaxpr proof

WORKER_POD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

V, B = 8, 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
tx, ty, _, _ = make_synthetic_cifar(jax.random.PRNGKey(0), num_classes=V,
                                    train_per_class=16, test_per_class=8,
                                    hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
host = jax.tree_util.tree_map(np.asarray, st0)
fresh = lambda: jax.tree_util.tree_map(jnp.asarray, host)
ke = jax.random.PRNGKey(1)
oracle = jax.jit(lambda k, s, a: E.sfpl_epoch(
    k, s, data, split, opt, opt, num_clients=V, batch_size=B, alpha=a),
    static_argnums=(2,))

mesh = ED.make_data_mesh(8, pods=2)
assert dict(mesh.shape) == {"pod": 2, "data": 4}, dict(mesh.shape)
data_dev = ED.shard_client_data(data, mesh)
md = lambda a, b: max(
    float(np.abs(np.asarray(x) - np.asarray(y)).max())
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)))

for name, alpha, kw in [
        ("sync-a1.0", 1.0, {}),
        ("submesh-a0.5", 0.5, dict(collector_pipeline="double_buffered",
                                   collector_submesh=True))]:
    st_ref, l_ref = oracle(ke, fresh(), alpha)
    sts = ED.shard_dcml_state(fresh(), mesh)
    ep = ED.make_sfpl_epoch_sharded(split, opt, opt, data_dev, mesh=mesh,
                                    num_clients=V, batch_size=B,
                                    alpha=alpha, **kw)
    sts, ls = ep(ke, sts)
    dl = float(np.abs(np.asarray(ls) - np.asarray(l_ref)).max())
    dcp, dsp = md(sts["cp"], st_ref["cp"]), md(sts["sp"], st_ref["sp"])
    assert dl < 1e-5 and dcp < 1e-5 and dsp < 1e-5, (name, dl, dcp, dsp)
    print("pod-oracle OK", name, flush=True)
print("all-pod-oracle OK")
"""

WORKER_JAXPR = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.core import round as RD

N, D = 64, 3
x = jnp.zeros((N, D))
perm = jnp.arange(N)

def counts(mesh, axis, alpha, streaming, submesh=None):
    if streaming:
        coll = RD.StreamingAllToAll(mesh=mesh, num_clients=8, axis=axis,
                                    alpha=alpha, submesh=submesh)
    else:
        coll = RD.MeshAllToAll(mesh=mesh, num_clients=8, axis=axis,
                               alpha=alpha)
    run = lambda v, p: coll.permute(v, coll.prepare(p, N))
    fwd = str(jax.make_jaxpr(run)(x, perm))
    w = jnp.ones((N, D))
    bwd = str(jax.make_jaxpr(
        jax.grad(lambda v: jnp.sum(run(v, perm) * w)))(x))
    return (fwd.count("all_to_all"), bwd.count("all_to_all"),
            fwd.count("sort["), bwd.count("sort["))

mesh1 = jax.make_mesh((8,), ("data",))
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))

# per-cell collective counts must be IDENTICAL between the 1-D and the
# pod mesh — the pod axis adds no all_to_alls — and the exchange path
# stays sort-free everywhere
for alpha, streaming, submesh in [(1.0, False, None), (0.5, False, None),
                                  (1.0, True, None), (0.5, True, True),
                                  (0.5, True, False)]:
    c1 = counts(mesh1, "data", alpha, streaming, submesh)
    c2 = counts(mesh2, ("pod", "data"), alpha, streaming, submesh)
    assert c1 == c2, (alpha, streaming, submesh, c1, c2)
    assert c1[2] == c1[3] == 0, (alpha, streaming, submesh, c1)
    assert c1[0] >= 1 and c1[1] > c1[0], (alpha, streaming, submesh, c1)
    print("jaxpr-parity OK", alpha, streaming, submesh, c1[:2],
          flush=True)
print("all-jaxpr OK")
"""


def _run_worker(tmp_path, code, tokens, timeout=540):
    w = tmp_path / "worker.py"
    w.write_text(code)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run([sys.executable, str(w)], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    for tok in tokens:
        assert tok in r.stdout, r.stdout + r.stderr
    return r.stdout


def test_pod_mesh_single_process_differential(tmp_path):
    _run_worker(tmp_path, WORKER_POD,
                ["pod-oracle OK sync-a1.0", "pod-oracle OK submesh-a0.5",
                 "all-pod-oracle OK"])


def test_pod_axis_jaxpr_collective_count(tmp_path):
    _run_worker(tmp_path, WORKER_JAXPR, ["all-jaxpr OK"])


# --------------------------------------------------------------------------
# 4. the tentpole: 2 coordinated processes x 4 devices each


def _pod_matrix_worker():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import engine as E
    from repro.core import engine_dist as ED
    from repro.data import make_synthetic_cifar, partition_positive_labels
    from repro.launch import multihost
    from repro.models import resnet as R
    from repro.optim import sgd_momentum

    V, B = 8, 8
    cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
    tx, ty, _, _ = make_synthetic_cifar(
        jax.random.PRNGKey(0), num_classes=V, train_per_class=16,
        test_per_class=8, hw=8)
    data = partition_positive_labels(tx, ty, V)
    split = E.make_resnet_split(cfg)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st0 = E.init_dcml_state(jax.random.PRNGKey(0),
                            lambda k: R.init(k, cfg), V, opt, opt)
    host = jax.tree_util.tree_map(np.asarray, st0)
    fresh = lambda: jax.tree_util.tree_map(jnp.asarray, host)
    ke = jax.random.PRNGKey(1)
    # the oracle runs UNsharded inside each process — a per-host
    # single-device reference, identical on every host by determinism
    oracle = jax.jit(lambda k, s, a: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=B,
        alpha=a), static_argnums=(2,))

    mesh = multihost.make_pod_mesh()
    assert dict(mesh.shape) == {"pod": 2, "data": 4}, dict(mesh.shape)
    assert ED.collector_axis(mesh) == ("pod", "data")
    data_dev = ED.shard_client_data(data, mesh)

    cells = [
        ("sync-a1.0", 1.0, {}),
        ("sync-a0.5", 0.5, {}),
        ("dbuf-a1.0", 1.0, dict(collector_pipeline="double_buffered",
                                collector_submesh=False)),
        ("dbuf-a0.5", 0.5, dict(collector_pipeline="double_buffered",
                                collector_submesh=False)),
        ("submesh-a1.0", 1.0, dict(collector_pipeline="double_buffered",
                                   collector_submesh=True)),
        ("submesh-a0.5", 0.5, dict(collector_pipeline="double_buffered",
                                   collector_submesh=True)),
    ]
    refs, out = {}, {}
    for name, alpha, kw in cells:
        if alpha not in refs:
            refs[alpha] = oracle(ke, fresh(), alpha)
        st_ref, l_ref = refs[alpha]
        sts = ED.shard_dcml_state(fresh(), mesh)
        epoch = ED.make_sfpl_epoch_sharded(
            split, opt, opt, data_dev, mesh=mesh, num_clients=V,
            batch_size=B, alpha=alpha, **kw)
        sts, ls = epoch(ke, sts)
        diff = lambda a, b: float(
            np.abs(multihost.host_value(a) - np.asarray(b)).max())
        md = lambda a, b: max(
            diff(x, y) for x, y in zip(jax.tree_util.tree_leaves(a),
                                       jax.tree_util.tree_leaves(b)))
        out[name] = dict(
            loss_diff=diff(ls, l_ref),
            client_diff=md(sts["cp"], st_ref["cp"]),
            server_diff=md(sts["sp"], st_ref["sp"]),
            losses=multihost.host_value(ls))
    return out


def test_multihost_differential_matrix(tmp_path):
    pytest.importorskip("cloudpickle")
    from _multihost import run_multiprocess
    results = run_multiprocess(_pod_matrix_worker, num_processes=2,
                               devices_per_process=4)
    assert len(results) == 2
    cells = sorted(results[0])
    assert cells == sorted(results[1])
    for name in cells:
        for pid, res in enumerate(results):
            cell = res[name]
            assert cell["loss_diff"] < 1e-5, (name, pid, cell)
            assert cell["client_diff"] < 1e-5, (name, pid, cell)
            assert cell["server_diff"] < 1e-5, (name, pid, cell)
        # both processes observed the identical global loss trajectory
        np.testing.assert_array_equal(results[0][name]["losses"],
                                      results[1][name]["losses"])
