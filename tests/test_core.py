"""SFPL core invariants: collector, BN policy, round engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.core import collector as C
from repro.core.bn_policy import fedavg, aggregate_bn_state, is_bn_path
from repro.core import engine as E
from repro.core.evaluate import (
    evaluate_split_iid, evaluate_split_noniid, weight_divergence)
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum


# --------------------------------------------------------------------------
# collector properties

@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 64))
def test_shuffle_deshuffle_inverse(n):
    key = jax.random.PRNGKey(n)
    x = jax.random.normal(key, (n, 5))
    perm = C.make_permutation(jax.random.fold_in(key, 1), n)
    tree = {"a": x, "y": jnp.arange(n)}
    shuf = C.shuffle(tree, perm)
    back = C.deshuffle(shuf, perm)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(back["y"]), np.arange(n))


def test_collect_uncollect_roundtrip():
    x = jnp.arange(24).reshape(4, 6)   # 4 clients x 6 samples
    pooled = C.collect({"x": x})
    assert pooled["x"].shape == (24,)
    back = C.uncollect(pooled, 4)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.asarray(x))


def test_collector_shuffle_is_differentiable_routing():
    """VJP of the collector gather must route gradients back to the source
    rows (the de-shuffle of Algorithm 1)."""
    x = jnp.eye(4)
    perm = jnp.array([2, 0, 3, 1])

    def f(x):
        return jnp.sum(C.distributed_shuffle(x, perm) * jnp.arange(4.0)[:, None])

    g = jax.grad(f)(x)
    # row perm[i]=j of x receives weight i
    expected = np.zeros((4, 4))
    for i, j in enumerate([2, 0, 3, 1]):
        expected[j] = i
    np.testing.assert_allclose(np.asarray(g), expected)


def test_global_collector_pool_and_return():
    coll = C.GlobalCollector(num_clients=3)
    key = jax.random.PRNGKey(0)
    acts = jax.random.normal(key, (3, 4, 7))     # (N, B, feat)
    labels = jnp.tile(jnp.arange(3)[:, None], (1, 4))
    a_shuf, y_shuf, perm = coll.shuffle_pool(key, acts, labels)
    assert a_shuf.shape == (12, 7)
    # de-shuffled gradients return as (N, B, feat) with exact routing
    grads = C.deshuffle({"g": a_shuf}, perm)["g"]
    np.testing.assert_allclose(np.asarray(grads.reshape(3, 4, 7)),
                               np.asarray(acts), rtol=1e-6)


# --------------------------------------------------------------------------
# BN aggregation policy

def _stacked_params():
    return {
        "conv1": {"w": jnp.stack([jnp.ones((2, 2)), 3 * jnp.ones((2, 2))])},
        "bn1": {"scale": jnp.stack([jnp.ones(2), 3 * jnp.ones(2)]),
                "bias": jnp.stack([jnp.zeros(2), jnp.ones(2)])},
    }


def test_fedavg_excludes_bn():
    out = fedavg(_stacked_params(), exclude_bn=True)
    np.testing.assert_allclose(np.asarray(out["conv1"]["w"][0]),
                               2 * np.ones((2, 2)))   # averaged
    np.testing.assert_allclose(np.asarray(out["bn1"]["scale"][0]),
                               np.ones(2))            # kept local
    np.testing.assert_allclose(np.asarray(out["bn1"]["scale"][1]),
                               3 * np.ones(2))


def test_fedavg_includes_bn_when_not_excluded():
    out = fedavg(_stacked_params(), exclude_bn=False)
    np.testing.assert_allclose(np.asarray(out["bn1"]["scale"][0]),
                               2 * np.ones(2))


def test_bn_state_aggregation_flag():
    state = {"bn1": {"mean": jnp.stack([jnp.zeros(2), 2 * jnp.ones(2)])}}
    kept = aggregate_bn_state(state, aggregate=False)
    np.testing.assert_allclose(np.asarray(kept["bn1"]["mean"][0]),
                               np.zeros(2))
    agg = aggregate_bn_state(state, aggregate=True)
    np.testing.assert_allclose(np.asarray(agg["bn1"]["mean"][0]),
                               np.ones(2))


def test_is_bn_path():
    paths = jax.tree_util.tree_flatten_with_path(_stacked_params())[0]
    names = {"/".join(str(getattr(k, "key", k)) for k in p): is_bn_path(p)
             for p, _ in paths}
    assert names["conv1/w"] is False
    assert names["bn1/scale"] is True


def test_weight_divergence_zero_for_identical():
    w = {"a": jnp.ones((3, 3))}
    assert float(weight_divergence(w, w)) == 0.0
    w2 = {"a": 2 * jnp.ones((3, 3))}
    assert float(weight_divergence(w2, w)) == pytest.approx(1.0)


# --------------------------------------------------------------------------
# round engines (integration, tiny scale)

@pytest.fixture(scope="module")
def tiny_setup():
    V = 4
    key = jax.random.PRNGKey(0)
    cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
    tx, ty, ex, ey = make_synthetic_cifar(
        key, num_classes=V, train_per_class=32, test_per_class=16, hw=16)
    data = partition_positive_labels(tx, ty, V)
    split = E.make_resnet_split(cfg)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    return V, cfg, data, (ex, ey), split, opt


def test_sfpl_learns_under_positive_labels(tiny_setup):
    V, cfg, data, (ex, ey), split, opt = tiny_setup
    st = E.init_dcml_state(jax.random.PRNGKey(0),
                           lambda k: R.init(k, cfg), V, opt, opt)
    step = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8,
        bn_mode="cmsd"))
    key = jax.random.PRNGKey(1)
    for _ in range(5):
        key, ke = jax.random.split(key)
        st, losses = step(ke, st)
    rep = evaluate_split_noniid(st, split, ex, ey, V, rmsd=False, batch=16)
    assert rep["accuracy"] > 60.0, rep   # chance = 25%


def test_sflv2_fails_under_positive_labels(tiny_setup):
    V, cfg, data, (ex, ey), split, opt = tiny_setup
    st = E.init_dcml_state(jax.random.PRNGKey(0),
                           lambda k: R.init(k, cfg), V, opt, opt)
    step = jax.jit(lambda k, s: E.sflv2_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8))
    key = jax.random.PRNGKey(1)
    for _ in range(5):
        key, ke = jax.random.split(key)
        st, losses = step(ke, st)
    rep = evaluate_split_iid(st, split, ex, ey, V, rmsd=True, batch=16)
    # collapses toward chance (paper Table I: 10% at 10 classes)
    assert rep["accuracy"] < 45.0, rep


def test_sfpl_loss_decreases(tiny_setup):
    V, cfg, data, _, split, opt = tiny_setup
    st = E.init_dcml_state(jax.random.PRNGKey(2),
                           lambda k: R.init(k, cfg), V, opt, opt)
    step = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8))
    key = jax.random.PRNGKey(3)
    st, first = step(key, st)
    for _ in range(3):
        key, ke = jax.random.split(key)
        st, last = step(ke, st)
    assert float(last.mean()) < float(first.mean())


# --------------------------------------------------------------------------
# SFPL-for-LM identity property

def test_sfpl_lm_identity_perm_equals_plain_loss():
    from repro.models.common import TransformerConfig
    from repro.models import transformer as T
    from repro.core.split_lm import sfpl_lm_loss
    key = jax.random.PRNGKey(0)
    cfg = TransformerConfig(num_layers=2, d_model=64, num_heads=4,
                            num_kv_heads=2, head_dim=16, d_ff=128,
                            vocab_size=97, remat=False,
                            compute_dtype="float32")
    p = T.init(key, cfg)
    batch = {"tokens": jax.random.randint(key, (4, 8), 0, 97),
             "labels": jax.random.randint(key, (4, 8), 0, 97)}
    plain, _ = T.loss_fn(p, batch, cfg)
    l_id, _ = sfpl_lm_loss(T, p, batch, cfg, perm=jnp.arange(4))
    assert float(plain) == pytest.approx(float(l_id), abs=1e-5)
    # any permutation leaves the (batch-permutation-invariant) loss equal
    l_p, _ = sfpl_lm_loss(T, p, batch, cfg, perm=jnp.array([2, 0, 3, 1]))
    assert float(plain) == pytest.approx(float(l_p), rel=1e-4)


# --------------------------------------------------------------------------
# collector alpha (accumulation threshold, Algorithm 1)

def test_collector_alpha_partial_flush_groups():
    from repro.core.collector import GlobalCollector
    key = jax.random.PRNGKey(0)
    # 4 clients x 3 samples; alpha=0.5 -> two flushes of 2 clients each
    coll = GlobalCollector(4, alpha=0.5)
    perm = coll.make_pool_perm(key, 12)
    p = np.asarray(perm)
    assert sorted(p.tolist()) == list(range(12))
    # no row crosses the flush boundary (rows 0-5 vs 6-11)
    assert set(p[:6]) == set(range(6))
    assert set(p[6:]) == set(range(6, 12))


def test_collector_alpha_one_is_global():
    from repro.core.collector import GlobalCollector
    key = jax.random.PRNGKey(1)
    coll = GlobalCollector(4, alpha=1.0)
    perm = np.asarray(coll.make_pool_perm(key, 12))
    assert sorted(perm.tolist()) == list(range(12))


# --------------------------------------------------------------------------
# permutation invariants (balanced collector + flush groups)

@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([2, 4, 8]), m=st.integers(1, 4))
def test_balanced_perm_is_valid_and_exactly_balanced(s, m):
    """make_balanced_perm must be a permutation routing exactly
    b/num_shards = n/s^2 rows between EVERY (src, dst) shard pair — the
    property that makes it drop-free at slack=1.0."""
    from repro.core.collector_dist import make_balanced_perm, pair_load
    n = s * s * m
    perm = np.asarray(make_balanced_perm(jax.random.PRNGKey(s * 100 + m),
                                         n, s))
    assert sorted(perm.tolist()) == list(range(n))
    load = pair_load(perm, s)
    np.testing.assert_array_equal(load, np.full((s, s), n // (s * s)))


@settings(max_examples=10, deadline=None)
@given(num=st.integers(2, 5), per_client=st.sampled_from([2, 3, 4]))
def test_pool_perm_stays_inside_flush_groups(num, per_client):
    """With alpha<1 the collector flushes in groups; make_pool_perm must
    never move a row across a flush boundary (here alpha=0.5 -> two pools
    of ceil(N/2) and floor(N/2) clients)."""
    from repro.core.collector import GlobalCollector
    N = 2 * num                       # e.g. alpha=0.5, N=10 -> two 5-pools
    n = N * per_client
    coll = GlobalCollector(N, alpha=0.5)
    perm = np.asarray(coll.make_pool_perm(
        jax.random.PRNGKey(N * 17 + per_client), n))
    assert sorted(perm.tolist()) == list(range(n))
    boundary = num * per_client       # rows of the first 5-client pool
    assert set(perm[:boundary]) == set(range(boundary))
    assert set(perm[boundary:]) == set(range(boundary, n))


def test_sfpl_epoch_with_partial_alpha_still_learns(tiny_setup):
    V, cfg, data, (ex, ey), split, opt = tiny_setup
    from repro.models import resnet as R
    st = E.init_dcml_state(jax.random.PRNGKey(5),
                           lambda k: R.init(k, cfg), V, opt, opt)
    step = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8,
        bn_mode="cmsd", alpha=0.5))
    key = jax.random.PRNGKey(6)
    for _ in range(5):
        key, ke = jax.random.split(key)
        st, _ = step(ke, st)
    rep = evaluate_split_noniid(st, split, ex, ey, V, rmsd=False, batch=16)
    # alpha=0.5 pools 2-of-4 clients per flush: still far above chance,
    # (generally below alpha=1 -- the paper's motivation for larger alpha)
    assert rep["accuracy"] > 50.0, rep
