"""Fault-tolerant multi-host bring-up and crash recovery.

Two layers:

  1. in-process retry coverage of ``multihost.initialize`` — a flaky
     coordinator join (monkeypatched ``jax.distributed.initialize``)
     succeeds within the backoff budget with the DETERMINISTIC sleep
     schedule (seeded by process_id), the half-initialized client is
     shut down between attempts, and an exhausted budget surfaces a
     ``RetryError`` naming the join;
  2. the tentpole kill-and-resume differential, 2 REAL coordinated JAX
     processes x 4 forced CPU devices (gloo), elastic FaultPlan dropouts
     every epoch:
       a. an uninterrupted 2-epoch run records the loss trajectory and
          final params;
       b. the same run under ``FaultPlan(kill_process=1, kill_epoch=1)``
          — worker 1 SIGKILLs ITSELF at the start of epoch 1, after the
          (collective) epoch-0 full-state checkpoint was written; the
          harness runs non-strict and tolerates the dead/blocked pair;
       c. a fresh pair restores the checkpoint (params + optimizer + BN
          stats + PRNG key + epoch) and finishes epoch 1.
     The resumed run's losses and final client/server params must match
     the uninterrupted run within 1e-5 on every process — a crashed
     worker costs the fleet one epoch of progress, not correctness.
"""
import numpy as np
import pytest

from repro.core.retry import RetryError, backoff_schedule


# --------------------------------------------------------------------------
# 1. retry/backoff on the production join path


def _patched_join(monkeypatch, fail_first, process_id=1, attempts=4):
    import jax
    from repro.launch import multihost
    calls, downs, slept = [], [], []

    def fake_init(**kw):
        calls.append(kw)
        if len(calls) <= fail_first:
            raise RuntimeError(f"connect refused {len(calls)}")

    monkeypatch.setattr(jax.distributed, "initialize", fake_init)
    monkeypatch.setattr(jax.distributed, "shutdown",
                        lambda: downs.append(1))
    multihost.initialize("127.0.0.1:1", num_processes=2,
                         process_id=process_id,
                         connect_attempts=attempts,
                         connect_base_delay=0.25, connect_max_delay=2.0,
                         sleep=slept.append)
    return calls, downs, slept


def test_initialize_retries_with_deterministic_backoff(monkeypatch):
    calls, downs, slept = _patched_join(monkeypatch, fail_first=2)
    assert len(calls) == 3           # 2 transient failures + 1 success
    assert len(downs) == 2           # half-set client reset each failure
    assert slept == backoff_schedule(4, base_delay=0.25, max_delay=2.0,
                                     seed=1)[:2]
    # every attempt carried the same join parameters
    assert all(kw["coordinator_address"] == "127.0.0.1:1" and
               kw["process_id"] == 1 for kw in calls)
    # different processes jitter differently (decorrelated herd)
    _, _, slept0 = _patched_join(monkeypatch, fail_first=2, process_id=0)
    assert slept0 != slept


def test_initialize_exhausts_budget(monkeypatch):
    import jax
    from repro.launch import multihost

    def always_down(**kw):
        raise ConnectionError("coordinator unreachable")

    monkeypatch.setattr(jax.distributed, "initialize", always_down)
    monkeypatch.setattr(jax.distributed, "shutdown", lambda: None)
    with pytest.raises(RetryError, match="process 1/2") as ei:
        multihost.initialize("127.0.0.1:1", num_processes=2, process_id=1,
                             connect_attempts=3, sleep=lambda _: None)
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last, ConnectionError)


# --------------------------------------------------------------------------
# 2. kill-and-resume across real coordinated processes


def _make_worker(mode, ckpt):
    """mode: 'full' (uninterrupted), 'fault' (worker 1 self-SIGKILLs at
    epoch 1, checkpoint after each finished epoch), 'resume' (restore the
    checkpoint and finish)."""

    def worker():
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.core import engine as E
        from repro.core import engine_dist as ED
        from repro.core.faults import FaultPlan, ensure_group_survivor
        from repro.data import (make_synthetic_cifar,
                                partition_positive_labels)
        from repro.launch import multihost
        from repro.models import resnet as R
        from repro.optim import sgd_momentum
        from repro import checkpoint as CK

        V, B, EPOCHS = 8, 8, 2
        cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
        tx, ty, _, _ = make_synthetic_cifar(
            jax.random.PRNGKey(0), num_classes=V, train_per_class=16,
            test_per_class=8, hw=8)
        data = partition_positive_labels(tx, ty, V)
        split = E.make_resnet_split(cfg)
        opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
        st0 = E.init_dcml_state(jax.random.PRNGKey(0),
                                lambda k: R.init(k, cfg), V, opt, opt)
        host = jax.tree_util.tree_map(np.asarray, st0)
        fresh = lambda: jax.tree_util.tree_map(jnp.asarray, host)

        mesh = multihost.make_pod_mesh()
        assert dict(mesh.shape) == {"pod": 2, "data": 4}, dict(mesh.shape)
        data_dev = ED.shard_client_data(data, mesh)
        epoch = ED.make_sfpl_epoch_sharded(
            split, opt, opt, data_dev, mesh=mesh, num_clients=V,
            batch_size=B, alpha=0.5)

        # every process reconstructs the identical fault schedule from
        # the seed — no coordination needed to agree on the mask
        plan = FaultPlan(V, seed=3, drop_rate=0.25,
                         kill_process=1 if mode == "fault" else None,
                         kill_epoch=1)

        key = jax.random.PRNGKey(1)
        start = 0
        if mode == "resume":
            st, key, start = CK.restore_train_state(ckpt, fresh(),
                                                    key_ref=key)
            st = ED.shard_dcml_state(st, mesh)
        else:
            st = ED.shard_dcml_state(fresh(), mesh)

        losses = {}
        for ep in range(start, EPOCHS):
            plan.maybe_kill(jax.process_index(), ep)
            mask, _ = plan.participation(ep)
            mask, _ = ensure_group_survivor(mask, V, alpha=0.5)
            key, ke = jax.random.split(key)
            st, ls = epoch(ke, st, participation=mask)
            losses[ep] = multihost.host_value(ls)
            if mode == "fault":
                # collective fetch on every process; process 0 writes
                CK.save_train_state(ckpt, st, key=key, epoch=ep + 1)

        fetch = lambda t: [multihost.host_value(x)
                           for x in jax.tree_util.tree_leaves(t)]
        return {"losses": losses, "cp": fetch(st["cp"]),
                "sp": fetch(st["sp"])}

    return worker


def test_kill_and_resume_reaches_parity(tmp_path):
    pytest.importorskip("cloudpickle")
    from _multihost import run_multiprocess
    ckpt = str(tmp_path / "state.npz")

    full = run_multiprocess(_make_worker("full", ckpt), num_processes=2,
                            devices_per_process=4)

    # worker 1 SIGKILLs itself at epoch 1; worker 0 is left blocked on a
    # collective its peer will never join — non-strict tolerates both
    # generous backstop: gloo errors out fast once the peer dies, so the
    # pair normally finishes well under this — but epoch-0 compile on a
    # loaded CI box must not eat the budget before the checkpoint lands
    faulted = run_multiprocess(_make_worker("fault", ckpt),
                               num_processes=2, devices_per_process=4,
                               strict=False, timeout=900)
    assert all(r is None for r in faulted), \
        "the killed pair must not report results"
    import os
    assert os.path.exists(ckpt), "epoch-0 checkpoint must have been written"

    resumed = run_multiprocess(_make_worker("resume", ckpt),
                               num_processes=2, devices_per_process=4)

    for pid in range(2):
        assert sorted(full[pid]["losses"]) == [0, 1]
        assert sorted(resumed[pid]["losses"]) == [1]  # one lost epoch
        dl = float(np.abs(resumed[pid]["losses"][1]
                          - full[pid]["losses"][1]).max())
        dc = max(float(np.abs(a - b).max()) for a, b in
                 zip(resumed[pid]["cp"], full[pid]["cp"]))
        ds = max(float(np.abs(a - b).max()) for a, b in
                 zip(resumed[pid]["sp"], full[pid]["sp"]))
        assert dl < 1e-5 and dc < 1e-5 and ds < 1e-5, (pid, dl, dc, ds)
    # both processes agree on the recovered trajectory
    np.testing.assert_array_equal(resumed[0]["losses"][1],
                                  resumed[1]["losses"][1])
