"""Data pipeline / optimizer / metrics / checkpoint / sharding-rule tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.data import (
    make_synthetic_cifar, partition_positive_labels, partition_iid,
    augment_batch, synthetic_token_stream)
from repro.optim import sgd_momentum, adamw, multistep_lr, cosine_lr
from repro.metrics import classification_report, confusion_matrix
from repro.checkpoint import save_checkpoint, restore_checkpoint


# --------------------------------------------------------------------------
# data

@settings(max_examples=6, deadline=None)
@given(v=st.integers(2, 6))
def test_positive_label_partition_is_single_class(v):
    key = jax.random.PRNGKey(v)
    x, y, _, _ = make_synthetic_cifar(key, num_classes=v,
                                      train_per_class=8, test_per_class=4,
                                      hw=8)
    data = partition_positive_labels(x, y, v)
    assert data["x"].shape[0] == v
    for k in range(v):
        assert bool(jnp.all(data["y"][k] == k))     # only positive labels


def test_iid_partition_covers_all_classes():
    key = jax.random.PRNGKey(0)
    x, y, _, _ = make_synthetic_cifar(key, num_classes=4,
                                      train_per_class=32, test_per_class=4,
                                      hw=8)
    data = partition_iid(key, x, y, 4)
    for k in range(4):
        assert len(np.unique(np.asarray(data["y"][k]))) >= 3


def test_synthetic_data_is_learnable_signal():
    """Class templates must be separable: nearest-template classification
    should beat chance by a wide margin."""
    key = jax.random.PRNGKey(1)
    x, y, ex, ey = make_synthetic_cifar(key, num_classes=4,
                                        train_per_class=16,
                                        test_per_class=16, hw=8)
    # class means as templates
    means = jnp.stack([x[y == k].mean(0) for k in range(4)])
    d = jnp.sum((ex[:, None] - means[None]) ** 2, axis=(2, 3, 4))
    acc = float(jnp.mean((jnp.argmin(d, 1) == ey)))
    assert acc > 0.7, acc


def test_augment_preserves_shape_dtype():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 16, 16, 3))
    y = augment_batch(key, x)
    assert y.shape == x.shape and y.dtype == x.dtype


def test_token_stream_shapes_and_labels_shifted():
    toks, labels = synthetic_token_stream(jax.random.PRNGKey(0), batch=3,
                                          seq_len=10, vocab=17)
    assert toks.shape == (3, 10) and labels.shape == (3, 10)
    np.testing.assert_array_equal(np.asarray(toks[:, 1:]),
                                  np.asarray(labels[:, :-1]))


# --------------------------------------------------------------------------
# optim

def test_sgd_momentum_matches_manual():
    opt = sgd_momentum(0.1, momentum=0.9)
    params = {"w": jnp.array([1.0, 2.0])}
    state = opt.init(params)
    g = {"w": jnp.array([1.0, 1.0])}
    p1, s1 = opt.update(g, state, params, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.9, 1.9])
    p2, s2 = opt.update(g, s1, p1, jnp.int32(1))
    # mu = 0.9*1 + 1 = 1.9 -> p -= 0.19
    np.testing.assert_allclose(np.asarray(p2["w"]), [0.71, 1.71],
                               rtol=1e-6)


def test_adamw_step_finite_and_decreases_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.array([5.0])}
    state = opt.init(params)
    for i in range(50):
        g = {"w": 2 * params["w"]}
        params, state = opt.update(g, state, params, jnp.int32(i))
    assert abs(float(params["w"][0])) < 1.0


def test_multistep_lr_milestones():
    fn = multistep_lr(0.1, [10, 20], 0.1)
    assert float(fn(jnp.int32(0))) == pytest.approx(0.1)
    assert float(fn(jnp.int32(10))) == pytest.approx(0.01)
    assert float(fn(jnp.int32(25))) == pytest.approx(0.001)


def test_cosine_lr_endpoints():
    fn = cosine_lr(1.0, 100, warmup=10, min_ratio=0.1)
    assert float(fn(jnp.int32(0))) == pytest.approx(0.0)
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


# --------------------------------------------------------------------------
# metrics

def test_confusion_and_report_perfect():
    preds = jnp.array([0, 1, 2, 0, 1, 2])
    rep = classification_report(preds, preds, 3)
    assert rep["accuracy"] == pytest.approx(100.0)
    assert rep["precision@1"] == pytest.approx(1.0)
    assert rep["f1"] == pytest.approx(1.0)


def test_report_chance_level():
    labels = jnp.array([0, 0, 1, 1])
    preds = jnp.array([0, 1, 0, 1])
    rep = classification_report(preds, labels, 2)
    assert rep["accuracy"] == pytest.approx(50.0)


# --------------------------------------------------------------------------
# checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "b": jnp.ones((4,), jnp.bfloat16)}
    path = os.path.join(tmp_path, "ck.npz")
    save_checkpoint(path, tree, step=7)
    restored, step = restore_checkpoint(path, tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]["w"]),
                                  np.asarray(tree["a"]["w"]))
    assert restored["b"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------
# sharding rules (via stub mesh: only axis names/shape consulted)

class _StubMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np
        self.devices = _np.empty(shape, object)


def test_param_spec_rules():
    from repro.sharding.rules import spec_for_param
    mesh = _StubMesh((16, 16), ("data", "model"))

    class K:  # fake DictKey
        def __init__(self, k):
            self.key = k

    def spec(path_str, shape):
        path = tuple(K(s) for s in path_str.split("/"))
        return tuple(spec_for_param(path, shape, mesh))

    assert spec("layers/sub0/attn/wq/w", (9, 4096, 4096)) == \
        (None, "data", "model")
    # kv out dim not divisible -> replicated out dim
    assert spec("layers/sub0/attn/wk/w", (9, 4096, 1024)) == \
        (None, "data", "model")
    assert spec("layers/sub0/attn/wk/w", (9, 4096, 1000)) == \
        (None, "data", None)
    assert spec("embed/table", (256000, 4096)) == ("model", "data")
    assert spec("layers/sub1/moe/wi", (12, 128, 5120, 8192)) == \
        (None, "model", "data", None)
    assert spec("layers/sub0/attn_norm/scale", (9, 4096)) == ()
    # xlstm blockdiag
    assert spec("layers/sub0/wq/w", (6, 1024, 4, 4)) == \
        (None, "model", None, None)


def test_state_sharding_kv_fallback_to_slots():
    """kv_heads=8 on model=16 must shard cache slots over model instead."""
    import jax as _jax
    from repro.sharding.rules import state_shardings
    if _jax.device_count() != 1:
        pytest.skip("host test")
    # use spec computation only via a real 1x1 mesh is trivial; check the
    # logic through the stub-free path with a real mesh of the right names
    mesh = _jax.make_mesh((1, 1), ("data", "model"))
    sds = {"sub0": {"k": _jax.ShapeDtypeStruct((4, 128, 32768, 8, 128),
                                               jnp.bfloat16)}}
    out = state_shardings(sds, mesh)
    assert out["sub0"]["k"] is not None  # smoke: callable path works


# --------------------------------------------------------------------------
# LM eval harness

def test_eval_lm_improves_after_training():
    """Training on the Markov stream must beat the untrained model on
    held-out batches (end-to-end train->eval->checkpoint loop)."""
    import jax as _jax
    from repro.configs import get_arch
    from repro.launch.eval import evaluate_lm
    from repro.launch.train import train_lm
    spec = get_arch("qwen3-8b")
    cfg = spec.make_smoke_config()
    p0 = spec.model.init(_jax.random.PRNGKey(0), cfg)
    before = evaluate_lm(spec, cfg, p0, batches=2, batch=4, seq=32, seed=9)
    losses = train_lm("qwen3-8b", steps=30, batch=8, seq=32, smoke=True,
                      lr=3e-3, log_every=100)
    assert losses[-1] < losses[0], (losses[0], losses[-1])
