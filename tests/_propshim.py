"""Property-test shim: real hypothesis when installed, otherwise a tiny
fallback that expands each strategy into a fixed handful of seeded examples
via ``pytest.mark.parametrize``.

The fallback keeps the test *bodies* untouched: ``@settings(...)`` becomes a
no-op and ``@given(a=st.integers(0, 8), b=st.sampled_from([...]))`` turns
into one parametrize mark whose cases are drawn deterministically (seeded by
the test name), always including the strategy bounds so edge cases stay
covered. This trades hypothesis' shrinking/search for a dependency-free,
reproducible sweep — good enough for CI where hypothesis may be absent.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import zlib

    import pytest

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        """A strategy is just `examples(rng, k)` -> list of k values."""

        def __init__(self, draw, edge_cases=()):
            self._draw = draw
            self._edge_cases = list(edge_cases)

        def examples(self, rng, k):
            out = list(self._edge_cases[:k])
            while len(out) < k:
                out.append(self._draw(rng))
            return out

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             edge_cases=(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options),
                             edge_cases=options)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)),
                             edge_cases=(False, True))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                             edge_cases=(min_value, max_value))

    strategies = _Strategies()

    def settings(*args, **kwargs):  # noqa: D401 - mirrors hypothesis API
        """No-op in fallback mode (example count is fixed by the shim)."""
        def deco(fn):
            return fn
        return deco

    def given(**strats):
        names = sorted(strats)

        def deco(fn):
            # deterministic per-test seed so runs are reproducible
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            columns = {n: strats[n].examples(rng, _FALLBACK_EXAMPLES)
                       for n in names}
            # zip columns: example i takes the i-th draw of every strategy,
            # with each column independently shuffled so edge cases from
            # different strategies don't always co-occur.
            for n in names:
                rng.shuffle(columns[n])
            cases = [pytest.param(*(columns[n][i] for n in names))
                     for i in range(_FALLBACK_EXAMPLES)]
            return pytest.mark.parametrize(",".join(names), cases)(fn)
        return deco
