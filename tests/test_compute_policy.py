"""Mixed-precision ComputePolicy: bf16 vs f32 parity matrix.

The bf16 compute path (f32 master params, bf16 convs/epilogues/exchange,
f32 BN statistics and loss) must track the f32 trajectory within
documented tolerances across every collector strategy and flush
threshold:

  * per-step loss delta <= 1e-2 (the ISSUE-pinned bound — one server
    update over a ~5k-param ResNet-8 at bf16's ~3 decimal digits);
  * full-model gradient max-abs delta <= 8e-2 at gradient magnitudes of
    O(1e-1) (measured ~3.8e-2 at this scale — bf16 rounding of conv
    activations accumulates over the 8-layer backward — with 2x headroom
    against seed drift);
  * master params and grads stay f32, smashed data becomes bf16.

Strategies run in a subprocess at 8 forced host devices (the device count
must be fixed before jax initializes), like tests/test_engine_dist.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

WORKER_DTYPE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.models.common import ComputePolicy
from repro.optim import sgd_momentum

V = 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split32 = E.make_resnet_split(cfg)
split16 = E.make_resnet_split(cfg, policy=ComputePolicy("bfloat16"))
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)
mesh = ED.make_data_mesh(8)
data_sh = ED.shard_client_data(data, mesh)

def fresh_dense():
    return jax.tree_util.tree_map(jnp.asarray, st0_host)

def fresh_sharded():
    return ED.shard_dcml_state(fresh_dense(), mesh)

ke = jax.random.split(jax.random.PRNGKey(1))[1]

# bf16 smashed data crosses the collector in bf16; master params stay f32
cp0 = jax.tree_util.tree_map(lambda t: t[0], st0["cp"])
cs0 = jax.tree_util.tree_map(lambda t: t[0], st0["cbn"])
a16, _ = split16.client_fwd(cp0, cs0, tx[:8])
assert a16.dtype == jnp.bfloat16, a16.dtype
assert all(l.dtype == jnp.float32
           for l in jax.tree_util.tree_leaves(st0["sp"]))
print("exchange-dtype OK")

# full-model grads: f32 dtype, bounded delta vs the f32 graph
p0 = {"client": cp0, "server": st0["sp"]}
s0 = {"client": cs0, "server": st0["sbn"]}
def gfn(split):
    return jax.grad(
        lambda p: split.full_loss(p, s0, tx[:16], ty[:16], True, None)[0])(p0)
g32, g16 = gfn(split32), gfn(split16)
gd = max(float(jnp.abs(a - b).max()) for a, b in
         zip(jax.tree_util.tree_leaves(g32), jax.tree_util.tree_leaves(g16)))
assert all(l.dtype == jnp.float32
           for l in jax.tree_util.tree_leaves(g16))
assert gd <= 8e-2, gd
print(f"grad-parity OK ({gd:.2e})")

# loss-trajectory matrix: {DenseTake, MeshAllToAll, StreamingAllToAll}
# x alpha {0.5, 1.0}. The f32 dense trajectory is THE reference per alpha
# (strategies agree to 1e-4 in f32 per tests/test_engine_dist.py, far
# below the bf16 bound).
for alpha in (0.5, 1.0):
    dense32 = jax.jit(lambda k, s, a=alpha: E.sfpl_epoch(
        k, s, data, split32, opt, opt, num_clients=V, batch_size=8,
        alpha=a))
    _, l_ref = dense32(ke, fresh_dense())
    l_ref = np.asarray(l_ref)

    dense16 = jax.jit(lambda k, s, a=alpha: E.sfpl_epoch(
        k, s, data, split16, opt, opt, num_clients=V, batch_size=8,
        alpha=a))
    _, l_d = dense16(ke, fresh_dense())
    runs = {"DenseTake": np.asarray(l_d)}

    sync16 = ED.make_sfpl_epoch_sharded(
        split16, opt, opt, data_sh, mesh=mesh, num_clients=V, batch_size=8,
        alpha=alpha, check_capacity=True)
    _, l_s = sync16(ke, fresh_sharded())
    runs["MeshAllToAll"] = np.asarray(l_s)

    stream16 = ED.make_sfpl_epoch_sharded(
        split16, opt, opt, data_sh, mesh=mesh, num_clients=V, batch_size=8,
        alpha=alpha, collector_pipeline="double_buffered")
    _, l_t = stream16(ke, fresh_sharded())
    runs["StreamingAllToAll"] = np.asarray(l_t)

    for name, l in runs.items():
        d = float(np.abs(l - l_ref).max())
        assert d <= 1e-2, (name, alpha, d)
    print(f"alpha={alpha} loss-parity OK")
print("dtype-matrix OK")
"""


@pytest.mark.parametrize("_", [0])
def test_bf16_policy_matches_f32_across_strategies(_, tmp_path):
    script = tmp_path / "worker_dtype.py"
    script.write_text(WORKER_DTYPE)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    for token in ("exchange-dtype OK", "grad-parity OK",
                  "alpha=0.5 loss-parity OK", "alpha=1.0 loss-parity OK",
                  "dtype-matrix OK"):
        assert token in res.stdout, res.stdout


class _FakeMesh:
    axis_names = ("data",)
    devices = np.empty((8,), dtype=object)


def test_exchange_bytes_halve_at_bf16():
    """Plan shapes are dtype-independent, so the bf16 activation exchange
    is exactly half the f32 wire bytes — for the sync strategy AND the
    per-group streamed strategy, at full and partial flushes."""
    from repro.core.round import MeshAllToAll, StreamingAllToAll
    n, row_elems = 64, 8 * 8 * 8
    for cls, alpha in ((MeshAllToAll, 1.0), (MeshAllToAll, 0.5),
                      (StreamingAllToAll, 0.5)):
        coll = cls(mesh=_FakeMesh(), num_clients=8, alpha=alpha)
        prep = coll.prepare(coll.make_perm(jax.random.PRNGKey(0), n), n)
        b32 = coll.exchange_bytes(prep, row_elems, jnp.float32)
        b16 = coll.exchange_bytes(prep, row_elems, jnp.bfloat16)
        assert b32 > 0 and b32 == 2 * b16, (cls.__name__, alpha, b32, b16)


def test_dense_take_exchange_bytes_zero():
    from repro.core.round import DenseTake
    coll = DenseTake(num_clients=8)
    assert coll.exchange_bytes(None, 512, jnp.float32) == 0
