"""Per-kernel allclose vs the pure-jnp oracles, with hypothesis sweeps over
shapes/dtypes (interpret mode executes the Pallas kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propshim import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.collector_permute.ops import collector_permute
from repro.kernels.collector_permute.ref import permute_ref


# --------------------------------------------------------------------------
# flash attention

@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    s=st.sampled_from([16, 64, 100, 128]),
    hk=st.sampled_from([(4, 2), (4, 4), (8, 1)]),
    d=st.sampled_from([32, 64]),
    causal=st.booleans(),
    window=st.sampled_from([None, 16]),
)
def test_flash_attention_matches_ref(b, s, hk, d, causal, window):
    h, k_heads = hk
    key = jax.random.PRNGKey(b * 1000 + s)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, k_heads, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, k_heads, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (2, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (2, 64, 2, 32)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


# --------------------------------------------------------------------------
# rmsnorm

@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 33),
    d=st.sampled_from([8, 100, 128, 256, 300]),
    offset=st.sampled_from([0.0, 1.0]),
)
def test_rmsnorm_matches_ref(rows, d, offset):
    key = jax.random.PRNGKey(rows * 7 + d)
    x = jax.random.normal(key, (rows, d), jnp.float32)
    scale = jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 0.1 + 1.0
    out = rmsnorm(x, scale, scale_offset=offset, interpret=True)
    ref = rmsnorm_ref(x, scale, scale_offset=offset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_rmsnorm_3d_bf16():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (4, 7, 96)).astype(jnp.bfloat16)
    s = jnp.ones((96,))
    out = rmsnorm(x, s, interpret=True)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------------
# collector permute

@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(2, 40),
    feat=st.sampled_from([16, 100, 512, 513]),
)
def test_collector_permute_matches_ref(rows, feat):
    key = jax.random.PRNGKey(rows + feat)
    x = jax.random.normal(key, (rows, feat), jnp.float32)
    perm = jax.random.permutation(jax.random.fold_in(key, 9), rows)
    out = collector_permute(x, perm, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(permute_ref(x, perm)))


def test_collector_permute_inverse_roundtrip():
    key = jax.random.PRNGKey(11)
    x = jax.random.normal(key, (24, 3, 17))
    perm = jax.random.permutation(jax.random.fold_in(key, 1), 24)
    shuf = collector_permute(x, perm, interpret=True)
    back = collector_permute(shuf, jnp.argsort(perm), interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# --------------------------------------------------------------------------
# sLSTM fused scan kernel

from repro.kernels.slstm_scan.ops import slstm_scan
from repro.kernels.slstm_scan.ref import slstm_scan_ref


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 5),
    s=st.sampled_from([4, 20, 64, 70]),
    hd=st.sampled_from([(2, 8), (4, 16), (1, 32)]),
)
def test_slstm_scan_matches_ref(b, s, hd):
    h, dh = hd
    key = jax.random.PRNGKey(b * 31 + s)
    ks = jax.random.split(key, 5)
    pres = [jax.random.normal(ks[i], (b, s, h, dh)) for i in range(4)]
    R = jax.random.normal(ks[4], (4, h, dh, dh)) * 0.3
    zero = jnp.zeros((b, h, dh))
    state0 = (zero, zero + 1e-6, zero - 1e30, zero)
    href, _ = slstm_scan_ref(*pres, R, state0)
    hker = slstm_scan(*pres, R, interpret=True)
    np.testing.assert_allclose(np.asarray(hker), np.asarray(href),
                               rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# fused BN affine + ReLU epilogue

from repro.kernels.bn_act.ops import bn_act
from repro.kernels.bn_act.ref import bn_act_ref


@settings(max_examples=12, deadline=None)
@given(
    rows=st.integers(1, 33),
    c=st.sampled_from([8, 100, 128, 300]),
    relu=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_bn_act_matches_ref(rows, c, relu, dtype):
    key = jax.random.PRNGKey(rows * 13 + c)
    x = jax.random.normal(key, (rows, c)).astype(dtype)
    a = jax.random.normal(jax.random.fold_in(key, 1), (c,)) * 0.5 + 1.0
    b = jax.random.normal(jax.random.fold_in(key, 2), (c,)) * 0.5
    out = bn_act(x, a, b, relu=relu, interpret=True)
    ref = bn_act_ref(x, a, b, relu=relu)
    assert out.dtype == dtype
    # tight f32 tolerance: the jitted dispatch may contract the affine
    # into an FMA, so the last ulp can differ from the eager oracle
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-6, atol=1e-6)


def test_bn_act_grads_match_ref():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (3, 5, 7, 33), jnp.float32)
    a = jax.random.normal(jax.random.fold_in(key, 1), (33,)) * 0.5 + 1.0
    b = jax.random.normal(jax.random.fold_in(key, 2), (33,)) * 0.5
    for relu in (True, False):
        f_k = lambda *o: jnp.sum(bn_act(*o, relu=relu, interpret=True) ** 2)
        f_r = lambda *o: jnp.sum(bn_act_ref(*o, relu=relu) ** 2)
        gk = jax.grad(f_k, argnums=(0, 1, 2))(x, a, b)
        gr = jax.grad(f_r, argnums=(0, 1, 2))(x, a, b)
        for u, v in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# fused softmax cross-entropy

from repro.kernels.softmax_xent.ops import softmax_xent
from repro.kernels.softmax_xent.ref import softmax_xent_ref


@settings(max_examples=12, deadline=None)
@given(
    rows=st.integers(1, 40),
    v=st.sampled_from([2, 10, 128, 200]),
    ignore_some=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_softmax_xent_matches_ref(rows, v, ignore_some, dtype):
    key = jax.random.PRNGKey(rows * 17 + v)
    logits = (jax.random.normal(key, (rows, v)) * 3).astype(dtype)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (rows,), 0, v)
    if ignore_some:
        labels = labels.at[::3].set(-100)
    out = softmax_xent(logits, labels, interpret=True)
    ref = softmax_xent_ref(logits, labels)
    np.testing.assert_allclose(float(out), float(ref), rtol=1e-5, atol=1e-6)


def test_softmax_xent_grads_match_ref():
    key = jax.random.PRNGKey(9)
    for dtype, tol in ((jnp.float32, 1e-5), (jnp.bfloat16, 1e-2)):
        logits = (jax.random.normal(key, (37, 11)) * 3).astype(dtype)
        labels = jax.random.randint(jax.random.fold_in(key, 1), (37,), 0, 11)
        labels = labels.at[5].set(-100)
        gk = jax.grad(
            lambda z: softmax_xent(z, labels, interpret=True))(logits)
        gr = jax.grad(lambda z: softmax_xent_ref(z, labels))(logits)
        assert gk.dtype == dtype
        np.testing.assert_allclose(np.asarray(gk, np.float32),
                                   np.asarray(gr, np.float32),
                                   rtol=tol, atol=tol)


def test_xlstm_model_with_pallas_slstm_matches_xla():
    from repro.models import xlstm as X
    key = jax.random.PRNGKey(0)
    base = dict(num_layers=2, d_model=32, num_heads=2, vocab_size=53,
                slstm_every=2, chunk_len=4, remat=False,
                compute_dtype="float32")
    cfg_x = X.XLSTMConfig(**base, slstm_impl="xla")
    cfg_p = X.XLSTMConfig(**base, slstm_impl="pallas_interpret")
    p = X.init(key, cfg_x)
    toks = jax.random.randint(key, (2, 8), 0, 53)
    lx, _ = X.forward(p, {"tokens": toks}, cfg_x, training=False)
    lp, _ = X.forward(p, {"tokens": toks}, cfg_p, training=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# route-plan bucket gathers: fuzzed shapes/dtypes, value and grad

from repro.kernels.collector_permute.ops import (
    bucket_permute, bucket_permute_ad, unbucket_permute,
    unbucket_permute_ad)
from repro.kernels.collector_permute.ref import (bucket_permute_ref,
                                                 unbucket_permute_ref)


@settings(max_examples=12, deadline=None)
@given(
    sc=st.sampled_from([(2, 3), (4, 4), (8, 2), (3, 7)]),
    feat=st.sampled_from([16, 100, 512, 513]),
    perm=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_bucket_permute_fuzz_matches_ref(sc, feat, perm, dtype):
    """Two-level (S, cap) send gather vs the jnp oracle: permutation index
    maps (the dense route-plan case) and maps with repeats/gaps (the
    slack-padded case reuses filler rows) both reproduce bit-for-bit."""
    S, cap = sc
    rows = S * cap
    key = jax.random.PRNGKey(S * 131 + cap * 17 + feat)
    x = jax.random.normal(key, (rows, feat)).astype(dtype)
    k2 = jax.random.fold_in(key, 1)
    flat = (jax.random.permutation(k2, rows) if perm
            else jax.random.randint(k2, (rows,), 0, rows))
    idx = flat.reshape(S, cap).astype(jnp.int32)
    out = bucket_permute(x, idx, interpret=True)
    assert out.dtype == dtype and out.shape == (rows, feat)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(bucket_permute_ref(x, idx)))


@settings(max_examples=12, deadline=None)
@given(
    rows=st.integers(2, 40),
    b=st.sampled_from([1, 5, 16, 33]),
    feat=st.sampled_from([16, 100, 513]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_unbucket_permute_fuzz_matches_ref(rows, b, feat, dtype):
    """Flat receive gather vs the jnp oracle, including B != R (the
    sub-mesh slab is narrower than the whole-mesh receive width)."""
    key = jax.random.PRNGKey(rows * 7 + b + feat)
    x = jax.random.normal(key, (rows, feat)).astype(dtype)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, rows)
    out = unbucket_permute(x, idx, interpret=True)
    assert out.dtype == dtype and out.shape == (b, feat)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(unbucket_permute_ref(x, idx)))


@settings(max_examples=8, deadline=None)
@given(
    sc=st.sampled_from([(2, 4), (4, 3), (8, 2)]),
    feat=st.sampled_from([16, 129]),
    perm=st.booleans(),
)
def test_bucket_gather_grads_match_ref(sc, feat, perm):
    """AD through the differentiable wrappers vs AD through the jnp
    oracles: repeats in the index map scatter-ADD into the source row, so
    gradients must accumulate, not overwrite."""
    S, cap = sc
    rows = S * cap
    key = jax.random.PRNGKey(S * 37 + feat)
    x = jax.random.normal(key, (rows, feat), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 2), (rows, feat))
    k2 = jax.random.fold_in(key, 1)
    flat = (jax.random.permutation(k2, rows) if perm
            else jax.random.randint(k2, (rows,), 0, rows))
    idx2 = flat.reshape(S, cap).astype(jnp.int32)
    gk = jax.grad(
        lambda x: jnp.sum(bucket_permute_ad(x, idx2, True) * w))(x)
    gr = jax.grad(
        lambda x: jnp.sum(bucket_permute_ref(x, idx2) * w))(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-6, atol=1e-6)
    gk = jax.grad(
        lambda x: jnp.sum(unbucket_permute_ad(x, flat, True) * w))(x)
    gr = jax.grad(
        lambda x: jnp.sum(unbucket_permute_ref(x, flat) * w))(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# fuzzed grad parity for the fused BN / xent epilogues (the fixed-shape
# grad cases above pin one layout; these sweep shapes and dtypes)

@settings(max_examples=8, deadline=None)
@given(
    rows=st.integers(1, 33),
    c=st.sampled_from([8, 100, 128, 300]),
    relu=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_bn_act_grads_fuzz(rows, c, relu, dtype):
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    key = jax.random.PRNGKey(rows * 19 + c)
    x = jax.random.normal(key, (rows, c)).astype(dtype)
    a = jax.random.normal(jax.random.fold_in(key, 1), (c,)) * 0.5 + 1.0
    b = jax.random.normal(jax.random.fold_in(key, 2), (c,)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 3), (rows, c))
    f_k = lambda *o: jnp.sum(
        bn_act(*o, relu=relu, interpret=True).astype(jnp.float32) * w)
    f_r = lambda *o: jnp.sum(
        bn_act_ref(*o, relu=relu).astype(jnp.float32) * w)
    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, a, b)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(gk, gr):
        assert u.dtype == v.dtype
        np.testing.assert_allclose(np.asarray(u, np.float32),
                                   np.asarray(v, np.float32),
                                   rtol=tol, atol=tol)


@settings(max_examples=8, deadline=None)
@given(
    rows=st.integers(1, 40),
    v=st.sampled_from([2, 10, 128, 200]),
    ignore_some=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_softmax_xent_grads_fuzz(rows, v, ignore_some, dtype):
    tol = 1e-5 if dtype == jnp.float32 else 1e-2
    key = jax.random.PRNGKey(rows * 23 + v)
    logits = (jax.random.normal(key, (rows, v)) * 3).astype(dtype)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (rows,), 0, v)
    if ignore_some:
        labels = labels.at[::3].set(-100)
    gk = jax.grad(
        lambda z: softmax_xent(z, labels, interpret=True))(logits)
    gr = jax.grad(lambda z: softmax_xent_ref(z, labels))(logits)
    assert gk.dtype == dtype
    np.testing.assert_allclose(np.asarray(gk, np.float32),
                               np.asarray(gr, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# fused quantize-permute wire kernels: value parity is BIT-EXACT vs the
# core.wire reference (both compute the scale as amax * (1/qmax), so XLA's
# constant rewrites cannot split them), grads are straight-through

from repro.core import wire as W
from repro.kernels.quant_permute.ops import (
    dequant_unbucket_permute, quant_bucket_permute,
    quant_dequant_roundtrip_ad)
from repro.kernels.quant_permute.ref import (dequant_unbucket_permute_ref,
                                             quant_bucket_permute_ref)


@settings(max_examples=12, deadline=None)
@given(
    sc=st.sampled_from([(2, 3), (4, 4), (8, 2), (3, 7)]),
    feat=st.sampled_from([16, 100, 512, 513]),
    perm=st.booleans(),
    wire=st.sampled_from(["int8", "float8_e4m3"]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_quant_bucket_permute_fuzz_matches_ref(sc, feat, perm, wire, dtype):
    """Fused quantize + send gather vs quantize_rows∘gather: quantized
    rows AND f32 scales must match bit-for-bit (the exchange's receive
    side dequantizes with whichever one traveled)."""
    S, cap = sc
    rows = S * cap
    key = jax.random.PRNGKey(S * 101 + cap * 13 + feat)
    x = (jax.random.normal(key, (rows, feat)) * 3).astype(dtype)
    k2 = jax.random.fold_in(key, 1)
    flat = (jax.random.permutation(k2, rows) if perm
            else jax.random.randint(k2, (rows,), 0, rows))
    idx = flat.reshape(S, cap).astype(jnp.int32)
    q, s = quant_bucket_permute(x, idx, wire_dtype=wire, interpret=True)
    qr, sr = quant_bucket_permute_ref(x, idx, wire)
    assert q.dtype == W.WIRE_DTYPES[wire] and q.shape == (rows, feat)
    assert s.dtype == jnp.float32 and s.shape == (rows,)
    np.testing.assert_array_equal(np.asarray(q).view(np.uint8),
                                  np.asarray(qr).view(np.uint8))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@settings(max_examples=12, deadline=None)
@given(
    rows=st.integers(2, 40),
    b=st.sampled_from([1, 5, 16, 33]),
    feat=st.sampled_from([16, 100, 513]),
    wire=st.sampled_from(["int8", "float8_e4m3"]),
    out_dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_dequant_unbucket_permute_fuzz_matches_ref(rows, b, feat, wire,
                                                   out_dtype):
    """Fused receive gather + dequantize vs gather∘dequantize_rows,
    including B != R (sub-mesh slabs) and index repeats (slack pad)."""
    key = jax.random.PRNGKey(rows * 11 + b + feat)
    x = jax.random.normal(key, (rows, feat)) * 2
    q, s = W.quantize_rows(x, wire)
    idx = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, rows)
    out = dequant_unbucket_permute(q, s, idx, out_dtype=out_dtype,
                                   interpret=True)
    ref = dequant_unbucket_permute_ref(q, s, idx, out_dtype)
    assert out.dtype == out_dtype and out.shape == (b, feat)
    np.testing.assert_array_equal(
        np.asarray(out, np.float32), np.asarray(ref, np.float32))


@pytest.mark.parametrize("wire,tol", [("int8", 2e-2), ("float8_e4m3", 2e-1)])
def test_quant_roundtrip_error_bound_and_zero_rows(wire, tol):
    """dequant(quant(x)) stays inside the wire grid's per-row error bound
    (relative to the row amax) and all-zero rows — the slack pad row's
    payload — survive exactly."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (12, 64), jnp.float32) * 5
    x = x.at[3].set(0.0)
    idx = jnp.arange(12, dtype=jnp.int32).reshape(4, 3)
    q, s = quant_bucket_permute(x, idx, wire_dtype=wire, interpret=True)
    out = dequant_unbucket_permute(q, s, jnp.arange(12, dtype=jnp.int32),
                                   out_dtype=jnp.float32, interpret=True)
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    err = np.abs(np.asarray(out) - np.asarray(x))
    assert np.all(err <= tol * np.maximum(amax, 1e-30))
    np.testing.assert_array_equal(np.asarray(out[3]), np.zeros(64))


@settings(max_examples=8, deadline=None)
@given(
    sc=st.sampled_from([(2, 4), (4, 3), (8, 2)]),
    feat=st.sampled_from([16, 129]),
    perm=st.booleans(),
    wire=st.sampled_from(["int8", "float8_e4m3"]),
)
def test_quant_roundtrip_grads_are_straight_through(sc, feat, perm, wire):
    """AD through the fused quantized round trip vs the UNQUANTIZED gather
    oracle: dequantize∘quantize is treated as identity, so the cotangent
    routes purely by the composed gather and scatter-ADDS on repeats —
    the convention plan_shuffle's backward exchange implements."""
    S, cap = sc
    rows = S * cap
    key = jax.random.PRNGKey(S * 43 + feat)
    x = jax.random.normal(key, (rows, feat), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 2), (rows, feat))
    k2 = jax.random.fold_in(key, 1)
    flat = (jax.random.permutation(k2, rows) if perm
            else jax.random.randint(k2, (rows,), 0, rows))
    send_idx = flat.reshape(S, cap).astype(jnp.int32)
    recv_idx = jax.random.permutation(jax.random.fold_in(key, 3), rows)
    gk = jax.grad(lambda x: jnp.sum(quant_dequant_roundtrip_ad(
        x, send_idx, recv_idx, wire, True) * w))(x)
    src = flat[recv_idx]
    gr = jax.grad(
        lambda x: jnp.sum(x[src] * w))(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-6, atol=1e-6)
