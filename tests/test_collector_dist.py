"""Explicit shard_map collector: run in a subprocess with 8 host devices
(the device count must be fixed before jax initializes, so these tests
spawn a worker script)."""
import os
import subprocess
import sys

import pytest

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.collector_dist import shuffle_shard_map, make_balanced_perm
from repro.core.collector import inverse_permutation

mesh = jax.make_mesh((8,), ("data",))
N, D = 64, 5
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (N, D))
xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P("data")))

# uniform random permutation (slack buffer covers imbalance)
perm = jax.random.permutation(jax.random.fold_in(key, 1), N)
out = shuffle_shard_map(xs, perm, mesh=mesh, slack=8.0)
np.testing.assert_allclose(np.asarray(out), np.asarray(x)[np.asarray(perm)],
                           rtol=1e-6)
print("uniform-perm OK")

# balanced permutation is drop-free at slack=1
bperm = make_balanced_perm(jax.random.fold_in(key, 2), N, 8)
assert sorted(np.asarray(bperm).tolist()) == list(range(N))
out2 = shuffle_shard_map(xs, bperm, mesh=mesh, slack=1.0)
np.testing.assert_allclose(np.asarray(out2),
                           np.asarray(x)[np.asarray(bperm)], rtol=1e-6)
print("balanced-perm OK")

# de-shuffle = shuffle with the inverse permutation
back = shuffle_shard_map(out2, inverse_permutation(bperm), mesh=mesh,
                         slack=1.0)
np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)
print("deshuffle OK")

# balanced perm mixes shards: every output shard must hold rows from
# every source shard (the IID-simulation property)
src_shard = np.asarray(bperm) // 8
for s in range(8):
    got = set(src_shard[s * 8:(s + 1) * 8].tolist())
    assert len(got) == 8, (s, got)
print("mixing OK")
"""


@pytest.mark.parametrize("_", [0])
def test_shard_map_collector(_, tmp_path):
    script = tmp_path / "worker.py"
    script.write_text(WORKER)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, res.stdout + res.stderr
    for token in ("uniform-perm OK", "balanced-perm OK", "deshuffle OK",
                  "mixing OK"):
        assert token in res.stdout, res.stdout
