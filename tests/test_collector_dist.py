"""Explicit shard_map collector: run in a subprocess with 8 host devices
(the device count must be fixed before jax initializes, so these tests
spawn a worker script)."""
import os
import subprocess
import sys

import numpy as np
import pytest

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.collector_dist import (
    shuffle_shard_map, make_balanced_perm, assert_pair_capacity,
    max_pair_load, pair_capacity)
from repro.core.collector import inverse_permutation

mesh = jax.make_mesh((8,), ("data",))
N, D = 64, 5
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (N, D))
xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P("data")))

# uniform random permutation (slack buffer covers imbalance)
perm = jax.random.permutation(jax.random.fold_in(key, 1), N)
out = shuffle_shard_map(xs, perm, mesh=mesh, slack=8.0)
np.testing.assert_allclose(np.asarray(out), np.asarray(x)[np.asarray(perm)],
                           rtol=1e-6)
print("uniform-perm OK")

# balanced permutation is drop-free at slack=1 (and passes the in-graph check)
bperm = make_balanced_perm(jax.random.fold_in(key, 2), N, 8)
assert sorted(np.asarray(bperm).tolist()) == list(range(N))
out2 = shuffle_shard_map(xs, bperm, mesh=mesh, slack=1.0,
                         check_capacity=True)
np.testing.assert_allclose(np.asarray(out2),
                           np.asarray(x)[np.asarray(bperm)], rtol=1e-6)
print("balanced-perm OK")

# de-shuffle = shuffle with the inverse permutation
back = shuffle_shard_map(out2, inverse_permutation(bperm), mesh=mesh,
                         slack=1.0)
np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)
print("deshuffle OK")

# autodiff through the sharded gather IS the gradient de-shuffle
w = jnp.arange(float(N))[:, None]
g = jax.grad(lambda v: jnp.sum(
    shuffle_shard_map(v, bperm, mesh=mesh, slack=1.0) * w))(xs)
inv = np.argsort(np.asarray(bperm))
np.testing.assert_allclose(np.asarray(g),
                           np.tile(inv[:, None], (1, D)), rtol=1e-6)
print("autodiff-deshuffle OK")

# Pallas collector_permute kernel on the local bucket permute
out_k = shuffle_shard_map(xs, bperm, mesh=mesh, slack=1.0, use_kernel=True)
np.testing.assert_allclose(np.asarray(out_k),
                           np.asarray(x)[np.asarray(bperm)], rtol=1e-6)
g_k = jax.grad(lambda v: jnp.sum(
    shuffle_shard_map(v, bperm, mesh=mesh, slack=1.0, use_kernel=True)
    * w))(xs)
np.testing.assert_allclose(np.asarray(g_k), np.asarray(g), rtol=1e-6)
print("kernel-path OK")

# balanced perm mixes shards: every output shard must hold rows from
# every source shard (the IID-simulation property)
src_shard = np.asarray(bperm) // 8
for s in range(8):
    got = set(src_shard[s * 8:(s + 1) * 8].tolist())
    assert len(got) == 8, (s, got)
print("mixing OK")

# --- capacity regression: adversarial perm at slack=1.0 ----------------
# every output shard pulls ALL its rows from one source shard -> per-pair
# load b=8 against capacity 2.
adv = jnp.roll(jnp.arange(N), -8)
assert max_pair_load(adv, 8) == 8
assert pair_capacity(N, 8, 1.0) == 2
try:
    assert_pair_capacity(adv, 8, slack=1.0)
    raise SystemExit("host guard did not raise")
except ValueError:
    print("capacity-host-guard OK")

# without the check, rows are silently dropped (zero-filled output)
bad = np.asarray(shuffle_shard_map(xs, adv, mesh=mesh, slack=1.0))
assert not np.allclose(bad, np.asarray(x)[np.asarray(adv)])
# overflow rows overwrite the last slot and invalidate it, so only the
# rank-0 row of each bucket survives: 7 of 8 output rows per shard are 0
assert (np.abs(bad).sum(axis=1) == 0).sum() == 8 * 7
print("capacity-silent-drop OK")

# with check_capacity=True the jitted program itself raises
try:
    r = shuffle_shard_map(xs, adv, mesh=mesh, slack=1.0,
                          check_capacity=True)
    r.block_until_ready()
    raise SystemExit("in-graph check did not raise")
except Exception as e:
    assert "capacity exceeded" in str(e) or "CpuCallback" in str(e), e
    print("capacity-ingraph OK")
"""


@pytest.mark.parametrize("_", [0])
def test_shard_map_collector(_, tmp_path):
    script = tmp_path / "worker.py"
    script.write_text(WORKER)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, res.stdout + res.stderr
    for token in ("uniform-perm OK", "balanced-perm OK", "deshuffle OK",
                  "autodiff-deshuffle OK", "kernel-path OK", "mixing OK",
                  "capacity-host-guard OK", "capacity-silent-drop OK",
                  "capacity-ingraph OK"):
        assert token in res.stdout, res.stdout


def test_pair_load_host_helpers():
    """pair_load math needs no devices: identity perm is diagonal, the
    rolled perm concentrates a full slab on one pair."""
    from repro.core.collector_dist import (
        pair_load, max_pair_load, pair_capacity, assert_pair_capacity)
    n, s = 32, 4
    ident = np.arange(n)
    load = pair_load(ident, s)
    assert load.sum() == n
    np.testing.assert_array_equal(load, np.diag([n // s] * s))
    adv = np.roll(ident, -(n // s))
    assert max_pair_load(adv, s) == n // s
    assert pair_capacity(n, s, 1.0) == n // s // s + 1
    with pytest.raises(ValueError, match="drop rows"):
        assert_pair_capacity(adv, s, slack=1.0)
    # generous slack passes
    assert_pair_capacity(adv, s, slack=float(s))
