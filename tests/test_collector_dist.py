"""Explicit shard_map collector: run in a subprocess with 8 host devices
(the device count must be fixed before jax initializes, so these tests
spawn a worker script)."""
import os
import subprocess
import sys

import numpy as np
import pytest
from _propshim import given, settings, strategies as st

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.collector_dist import (
    shuffle_shard_map, make_balanced_perm, assert_pair_capacity,
    max_pair_load, pair_capacity)
from repro.core.collector import inverse_permutation

mesh = jax.make_mesh((8,), ("data",))
N, D = 64, 5
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (N, D))
xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P("data")))

# uniform random permutation (slack buffer covers imbalance)
perm = jax.random.permutation(jax.random.fold_in(key, 1), N)
out = shuffle_shard_map(xs, perm, mesh=mesh, slack=8.0)
np.testing.assert_allclose(np.asarray(out), np.asarray(x)[np.asarray(perm)],
                           rtol=1e-6)
print("uniform-perm OK")

# balanced permutation is drop-free at slack=1 (and passes the in-graph check)
bperm = make_balanced_perm(jax.random.fold_in(key, 2), N, 8)
assert sorted(np.asarray(bperm).tolist()) == list(range(N))
out2 = shuffle_shard_map(xs, bperm, mesh=mesh, slack=1.0,
                         check_capacity=True)
np.testing.assert_allclose(np.asarray(out2),
                           np.asarray(x)[np.asarray(bperm)], rtol=1e-6)
print("balanced-perm OK")

# de-shuffle = shuffle with the inverse permutation
back = shuffle_shard_map(out2, inverse_permutation(bperm), mesh=mesh,
                         slack=1.0)
np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-6)
print("deshuffle OK")

# autodiff through the sharded gather IS the gradient de-shuffle
w = jnp.arange(float(N))[:, None]
g = jax.grad(lambda v: jnp.sum(
    shuffle_shard_map(v, bperm, mesh=mesh, slack=1.0) * w))(xs)
inv = np.argsort(np.asarray(bperm))
np.testing.assert_allclose(np.asarray(g),
                           np.tile(inv[:, None], (1, D)), rtol=1e-6)
print("autodiff-deshuffle OK")

# Pallas collector_permute kernel on the local bucket permute
out_k = shuffle_shard_map(xs, bperm, mesh=mesh, slack=1.0, use_kernel=True)
np.testing.assert_allclose(np.asarray(out_k),
                           np.asarray(x)[np.asarray(bperm)], rtol=1e-6)
g_k = jax.grad(lambda v: jnp.sum(
    shuffle_shard_map(v, bperm, mesh=mesh, slack=1.0, use_kernel=True)
    * w))(xs)
np.testing.assert_allclose(np.asarray(g_k), np.asarray(g), rtol=1e-6)
print("kernel-path OK")

# balanced perm mixes shards: every output shard must hold rows from
# every source shard (the IID-simulation property)
src_shard = np.asarray(bperm) // 8
for s in range(8):
    got = set(src_shard[s * 8:(s + 1) * 8].tolist())
    assert len(got) == 8, (s, got)
print("mixing OK")

# grouped balanced perm (alpha<1 flush groups): exchange at the auto-sized
# slack is exact and passes the in-graph capacity check
from repro.core.collector_dist import (
    make_grouped_balanced_perm, grouped_perm_slack)
rows = [32, 32]                      # two flush groups of 4 shards each
gperm = make_grouped_balanced_perm(jax.random.fold_in(key, 3), N, 8, rows)
gslack = grouped_perm_slack(N, 8, rows)
outg = shuffle_shard_map(xs, gperm, mesh=mesh, slack=gslack,
                         check_capacity=True)
np.testing.assert_allclose(np.asarray(outg),
                           np.asarray(x)[np.asarray(gperm)], rtol=1e-6)
print("grouped-perm OK")

# uniform perm at the probe-sized slack: exact, capacity check on
from repro.core.collector_dist import uniform_auto_slack
uslack = uniform_auto_slack(N, 8)
outu = shuffle_shard_map(xs, perm, mesh=mesh, slack=uslack,
                         check_capacity=True)
np.testing.assert_allclose(np.asarray(outu),
                           np.asarray(x)[np.asarray(perm)], rtol=1e-6)
print("auto-slack OK")

# --- capacity regression: adversarial perm at slack=1.0 ----------------
# (LAST: the deliberately-triggered in-graph callback errors surface
# asynchronously and would poison later computations)
# every output shard pulls ALL its rows from one source shard -> per-pair
# load b=8 against capacity 2.
adv = jnp.roll(jnp.arange(N), -8)
assert max_pair_load(adv, 8) == 8
assert pair_capacity(N, 8, 1.0) == 2
try:
    assert_pair_capacity(adv, 8, slack=1.0)
    raise SystemExit("host guard did not raise")
except ValueError:
    print("capacity-host-guard OK")

# without the check, overflow rows are dropped (zero-filled output) — and
# ONLY the overflow rows: the route plan sends them to an OOB slot, so the
# cap=2 in-capacity rows of each bucket land intact (the old exchange let
# each overflow clobber the slot cap-1 row, losing 7 of 8 rows per shard)
bad = np.asarray(shuffle_shard_map(xs, adv, mesh=mesh, slack=1.0))
oracle = np.asarray(x)[np.asarray(adv)]
assert not np.allclose(bad, oracle)
zero = np.abs(bad).sum(axis=1) == 0
assert zero.sum() == 8 * 6, zero.sum()
np.testing.assert_allclose(bad[~zero], oracle[~zero], rtol=1e-6)
print("capacity-silent-drop OK")

# with check_capacity=True the jitted program itself raises
try:
    r = shuffle_shard_map(xs, adv, mesh=mesh, slack=1.0,
                          check_capacity=True)
    r.block_until_ready()
    raise SystemExit("in-graph check did not raise")
except Exception as e:
    assert "capacity exceeded" in str(e) or "CpuCallback" in str(e), e
    print("capacity-ingraph OK")
"""


@pytest.mark.parametrize("_", [0])
def test_shard_map_collector(_, tmp_path):
    script = tmp_path / "worker.py"
    script.write_text(WORKER)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, res.stdout + res.stderr
    for token in ("uniform-perm OK", "balanced-perm OK", "deshuffle OK",
                  "autodiff-deshuffle OK", "kernel-path OK", "mixing OK",
                  "capacity-host-guard OK", "capacity-silent-drop OK",
                  "capacity-ingraph OK", "grouped-perm OK",
                  "auto-slack OK"):
        assert token in res.stdout, res.stdout


def test_local_permute_order_in_range():
    import jax
    from repro.core.collector_dist import make_balanced_perm
    for seed, s in [(0, 2), (1, 4), (2, 8), (3, 8)]:
        n = s * s * 4
        b = n // s
        perms = [np.random.default_rng(seed).permutation(n),
                 np.asarray(make_balanced_perm(jax.random.PRNGKey(seed),
                                               n, s))]
        for perm in perms:
            inv = np.argsort(perm)
            for sid in range(s):
                out_pos = inv[np.arange(b) + sid * b]
                order = np.argsort(out_pos // b)
                assert order.min() >= 0
                assert order.max() < b
                assert np.array_equal(np.sort(order), np.arange(b))


@settings(max_examples=10, deadline=None)
@given(s_g=st.sampled_from([1, 2, 4]), groups=st.integers(2, 4),
       m=st.integers(1, 3))
def test_grouped_perm_never_mixes_flush_groups(s_g, groups, m):
    """Sharded flush groups are sealed: every row of a grouped balanced
    permutation stays inside its group's contiguous range, and within a
    multi-shard group the exchange is exactly balanced."""
    import jax
    from repro.core.collector_dist import (
        make_grouped_balanced_perm, pair_load)
    b = s_g * m                       # per-shard slab, divisible by s_g
    num_shards = s_g * groups
    n = num_shards * b
    rows = [s_g * b] * groups
    perm = np.asarray(make_grouped_balanced_perm(
        jax.random.PRNGKey(s_g * 100 + groups * 10 + m), n, num_shards,
        rows))
    assert sorted(perm.tolist()) == list(range(n))
    start = 0
    for size in rows:
        seg = perm[start:start + size]
        assert seg.min() >= start
        assert seg.max() < start + size
        start += size
    load = pair_load(perm, num_shards)
    for g in range(groups):
        blk = load[g * s_g:(g + 1) * s_g, g * s_g:(g + 1) * s_g]
        np.testing.assert_array_equal(blk, np.full((s_g, s_g), b // s_g))
    assert load.sum() == n            # nothing routed across groups


def test_grouped_perm_slack_covers_exact_loads():
    """The auto-sized slack holds the deterministic bucket loads of grouped
    balanced permutations, and resolves to the drop-free 1.0 for one
    global flush."""
    from repro.core.collector_dist import (
        grouped_perm_slack, max_pair_load, make_grouped_balanced_perm,
        pair_capacity)
    import jax
    assert grouped_perm_slack(64, 8, [64]) == 1.0
    for rows in ([32, 32], [16, 16, 16, 16], [8] * 8):
        slack = grouped_perm_slack(64, 8, rows)
        perm = make_grouped_balanced_perm(jax.random.PRNGKey(0), 64, 8,
                                          rows)
        assert max_pair_load(perm, 8) <= pair_capacity(64, 8, slack)


def test_grouped_perm_in_slab_groups():
    """Flush groups smaller than a shard slab shuffle in place: sealed,
    valid, diagonal loads covered by the auto slack."""
    import jax
    from repro.core.collector_dist import (
        make_grouped_balanced_perm, grouped_perm_slack, pair_load,
        pair_capacity)
    rows = [8, 8, 8, 8]
    perm = np.asarray(make_grouped_balanced_perm(
        jax.random.PRNGKey(0), 32, 2, rows))
    assert sorted(perm.tolist()) == list(range(32))
    start = 0
    for size in rows:
        seg = perm[start:start + size]
        assert seg.min() >= start
        assert seg.max() < start + size
        start += size
    load = pair_load(perm, 2)
    np.testing.assert_array_equal(load, np.diag([16, 16]))
    assert load.max() <= pair_capacity(32, 2,
                                       grouped_perm_slack(32, 2, rows))


def test_uniform_auto_slack_covers_probe_loads():
    from repro.core.collector_dist import (
        uniform_auto_slack, pair_capacity, max_pair_load)
    n, s = 64, 8
    cap = pair_capacity(n, s, uniform_auto_slack(n, s))
    rng = np.random.default_rng(0)
    for _ in range(16):
        assert max_pair_load(rng.permutation(n), s) < cap
    # grouped probing respects flush boundaries and still fits
    cap_g = pair_capacity(n, s, uniform_auto_slack(n, s, [32, 32]))
    assert cap_g >= 2


def test_pair_load_host_helpers():
    """pair_load math needs no devices: identity perm is diagonal, the
    rolled perm concentrates a full slab on one pair."""
    from repro.core.collector_dist import (
        pair_load, max_pair_load, pair_capacity, assert_pair_capacity)
    n, s = 32, 4
    ident = np.arange(n)
    load = pair_load(ident, s)
    assert load.sum() == n
    np.testing.assert_array_equal(load, np.diag([n // s] * s))
    adv = np.roll(ident, -(n // s))
    assert max_pair_load(adv, s) == n // s
    assert pair_capacity(n, s, 1.0) == n // s // s + 1
    with pytest.raises(ValueError, match="drop rows"):
        assert_pair_capacity(adv, s, slack=1.0)
    # generous slack passes
    assert_pair_capacity(adv, s, slack=float(s))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 9999), tail=st.sampled_from(["slab", "in_slab"]))
def test_grouped_perm_seals_mixed_layouts(seed, tail):
    """Sealing is a property of EVERY valid flush layout, not just the
    uniform-span ones: a multi-slab balanced group followed by
    single-slab or in-slab groups stays sealed under arbitrary keys, and
    no row ever routes across a group boundary."""
    import jax
    from repro.core.collector_dist import (make_grouped_balanced_perm,
                                           pair_load)
    num_shards, b = 4, 8
    n = num_shards * b
    rows = [2 * b] + ([b, b] if tail == "slab" else [b // 2] * 4)
    perm = np.asarray(make_grouped_balanced_perm(
        jax.random.PRNGKey(seed), n, num_shards, rows))
    assert sorted(perm.tolist()) == list(range(n))
    start = 0
    for size in rows:
        seg = perm[start:start + size]
        assert seg.min() >= start and seg.max() < start + size
        start += size
    load = pair_load(perm, num_shards)
    # the leading 2-slab group is an exactly balanced exchange between
    # shards 0 and 1; the tail groups never leave their own slab
    np.testing.assert_array_equal(load[:2, :2],
                                  np.full((2, 2), b // 2))
    np.testing.assert_array_equal(load[2:, 2:], np.diag([b, b]))
    assert load.sum() == n


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([2, 4, 8]), mult=st.sampled_from([1, 2]),
       grouped=st.booleans())
def test_uniform_auto_slack_probe_stream_never_exceeded(s, mult, grouped):
    """The probed uniform cap is never exceeded by ANY permutation of the
    probe's own sample stream (rng seed 0, 16 draws, flush structure
    honoured) — the margin row keeps every draw strictly inside. The
    sampled perms ARE the probe's (re-drawn from its seed): the bound is
    empirical, so fresh random draws are exactly what the forced-on
    in-graph capacity check exists for."""
    from repro.core.collector_dist import (max_pair_load, pair_capacity,
                                           uniform_auto_slack)
    n = s * s * 4 * mult
    sizes = [n // 2, n // 2] if grouped else None
    cap = pair_capacity(n, s, uniform_auto_slack(n, s, sizes))
    rng = np.random.default_rng(0)
    for _ in range(16):
        if sizes:
            parts, start = [], 0
            for size in sizes:
                parts.append(rng.permutation(size) + start)
                start += size
            perm = np.concatenate(parts)
        else:
            perm = rng.permutation(n)
        assert max_pair_load(perm, s) < cap


@settings(max_examples=8, deadline=None)
@given(span=st.sampled_from([1, 2, 4]), shards=st.sampled_from([4, 8]),
       mult=st.sampled_from([1, 2]))
def test_balanced_stream_slack_probe_stream_never_exceeded(span, shards,
                                                           mult):
    """The streamed whole-mesh fallback's probed balanced cap covers every
    draw of the probe's own permutation family (balanced over ``span``
    blocks, uniform in place at span <= 1, measured against the fine
    slabs), and the slack never exceeds the capacity-safe ``shards``
    ceiling it replaces."""
    from repro.core.collector_dist import (_np_balanced_perm,
                                           balanced_stream_slack,
                                           max_pair_load, pair_capacity)
    n = span * span * shards * mult
    slack = balanced_stream_slack(n, shards, span)
    assert slack <= shards
    cap = pair_capacity(n, shards, slack)
    rng = np.random.default_rng(0)
    for _ in range(16):
        perm = (_np_balanced_perm(rng, n, span) if span > 1
                else rng.permutation(n))
        assert max_pair_load(perm, shards) < cap
