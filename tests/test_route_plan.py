"""Route-plan collector: the plan-based exchange must (a) reproduce the
dense oracle bit-for-bit — forward AND gradients — across collector modes,
flush structures, and pipelines, (b) lower to exactly ONE all_to_all per
exchange direction with no sorts on the exchange path, and (c) never let
an overflowing row clobber an in-capacity row at undersized slack.

Multi-shard behavior runs in a subprocess with 8 forced host devices (the
device count must be fixed before jax initializes); structural jaxpr
inspection and host-side plan math run in-process.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

WORKER_PLAN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.collector_dist import (
    build_route_plans, exact_pair_cap, make_balanced_perm, pair_capacity,
    plan_shuffle, shuffle_shard_map)

mesh = jax.make_mesh((8,), ("data",))
N, D = 64, 5
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (N, D))
xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P("data")))

# dense plans for a balanced perm: exact capacity, no overflow accounting,
# zero slack padding (the send buffer is exactly the b-row slab)
bperm = make_balanced_perm(jax.random.fold_in(key, 2), N, 8)
cap = exact_pair_cap(N, 8)
plans = jax.jit(lambda p: build_route_plans(p, 8, cap=cap,
                                            may_drop=False))(bperm)
fwd, bwd = plans
assert fwd.dense and bwd.dense
assert fwd.overflow is None
assert fwd.send_idx.shape == (8, N // 8), fwd.send_idx.shape
out = jax.jit(lambda x, pl: plan_shuffle(x, pl, mesh=mesh))(xs, plans)
np.testing.assert_allclose(np.asarray(out),
                           np.asarray(x)[np.asarray(bperm)], rtol=1e-6)
print("dense-plan OK")

# autodiff through plan_shuffle routes gradients by the BACKWARD plan
w = jnp.arange(float(N))[:, None]
g = jax.grad(lambda v: jnp.sum(
    plan_shuffle(v, plans, mesh=mesh) * w))(xs)
inv = np.argsort(np.asarray(bperm))
np.testing.assert_allclose(np.asarray(g),
                           np.tile(inv[:, None], (1, D)), rtol=1e-6)
print("plan-grad OK")

# kernelized gathers agree with the jnp path, forward and backward
out_k = jax.jit(lambda x, pl: plan_shuffle(x, pl, mesh=mesh,
                                           use_kernel=True))(xs, plans)
np.testing.assert_allclose(np.asarray(out_k), np.asarray(out), rtol=1e-6)
g_k = jax.grad(lambda v: jnp.sum(
    plan_shuffle(v, plans, mesh=mesh, use_kernel=True) * w))(xs)
np.testing.assert_allclose(np.asarray(g_k), np.asarray(g), rtol=1e-6)
print("plan-kernel OK")

# overflow NO-CLOBBER regression at undersized slack: the rolled perm
# routes all b=8 rows of each source slab to one destination pair against
# capacity 2. Every output row must be EITHER exact (the in-capacity rows
# — the old exchange corrupted one of these per overflow by writing
# through slot cap-1) OR zero (the overflowing rows), and the zero count
# must equal exactly the overflow: 6 dropped rows per shard, never more.
adv = jnp.roll(jnp.arange(N), -8)
assert pair_capacity(N, 8, 1.0) == 2
bad = np.asarray(shuffle_shard_map(xs, adv, mesh=mesh, slack=1.0))
oracle = np.asarray(x)[np.asarray(adv)]
zero = np.abs(bad).sum(axis=1) == 0
np.testing.assert_allclose(bad[~zero], oracle[~zero], rtol=1e-6)
assert int(zero.sum()) == 8 * 6, int(zero.sum())
print("no-clobber OK")

# (LAST: the deliberately-triggered in-graph callback error surfaces
# asynchronously and would poison later collectives) — a balanced-mode
# collector with check_capacity=True must RAISE on a mis-declared perm
# (identity: diagonal load b=8 vs exact cap 1), not silently misroute:
# the exact-capacity plan keeps overflow accounting when checking is on.
from repro.core import round as RD
coll = RD.MeshAllToAll(mesh=mesh, num_clients=8, check_capacity=True)
try:
    r = jax.jit(lambda v, p: coll.permute(v, p))(xs, jnp.arange(N))
    r.block_until_ready()
    raise SystemExit("balanced check_capacity did not raise")
except SystemExit:
    raise
except Exception as e:
    assert "capacity exceeded" in str(e) or "CpuCallback" in str(e), e
    print("balanced-check OK")
"""

WORKER_ORACLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

V = 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)
mesh = ED.make_data_mesh(8)
data_sh = ED.shard_client_data(data, mesh)

def fresh():
    return ED.shard_dcml_state(
        jax.tree_util.tree_map(jnp.asarray, st0_host), mesh)

ke = jax.random.PRNGKey(1)
single = jax.jit(lambda k, s, a: E.sfpl_epoch(
    k, s, data, split, opt, opt, num_clients=V, batch_size=8, alpha=a),
    static_argnums=2)

# plan-path parity vs the DenseTake oracle: forward loss trajectories AND
# the gradient trajectories (client params after the epoch reflect the
# full shuffle -> server grad -> route-back round trip) for every
# mode x alpha x pipeline cell
for alpha in (0.25, 1.0):
    st_ref = jax.tree_util.tree_map(jnp.asarray, st0_host)
    st_ref, l_ref = single(ke, st_ref, alpha)
    l_ref = np.asarray(l_ref)
    for mode in ("balanced", "uniform"):
        for pipe in ("sync", "double_buffered"):
            ep = ED.make_sfpl_epoch_sharded(
                split, opt, opt, data_sh, mesh=mesh, num_clients=V,
                batch_size=8, alpha=alpha, collector_mode=mode,
                collector_pipeline=pipe)
            st, l = ep(ke, fresh())
            d = float(np.abs(np.asarray(l) - l_ref).max())
            assert d <= 1e-5, (alpha, mode, pipe, d)
            for a, b in zip(jax.tree_util.tree_leaves(st_ref["cp"]),
                            jax.tree_util.tree_leaves(st["cp"])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
            print(f"oracle-parity OK alpha={alpha} mode={mode} "
                  f"pipe={pipe} ({d:.2e})")
print("all-oracle-parity OK")
"""


def _run_worker(tmp_path, name, src, timeout):
    script = tmp_path / name
    script.write_text(src)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


@pytest.mark.parametrize("_", [0])
def test_plan_exchange_semantics(_, tmp_path):
    """Dense plans, plan gradients, kernelized gathers, and the overflow
    no-clobber fix at 8 forced host devices."""
    out = _run_worker(tmp_path, "worker_plan.py", WORKER_PLAN, 420)
    for token in ("dense-plan OK", "plan-grad OK", "plan-kernel OK",
                  "no-clobber OK", "balanced-check OK"):
        assert token in out, out


@pytest.mark.parametrize("_", [0])
def test_plan_path_matches_dense_oracle(_, tmp_path):
    """Forward + gradient trajectory parity vs the DenseTake oracle across
    mode x alpha x pipeline at 8 forced host devices (<= 1e-5)."""
    out = _run_worker(tmp_path, "worker_oracle.py", WORKER_ORACLE, 580)
    assert "all-oracle-parity OK" in out, out


def test_plan_exchange_is_one_collective_per_direction():
    """Jaxpr inspection: the plan exchange lowers to exactly ONE
    all_to_all forward, exactly TWO for forward+backward (one per
    direction) — no pos/valid collectives — and neither the exchange nor
    the plan build contains a single sort."""
    from repro.core.collector_dist import (build_route_plans,
                                           exact_pair_cap, plan_shuffle)
    mesh = jax.make_mesh((1,), ("data",))
    n = 16
    x = jnp.zeros((n, 3))
    perm = jax.random.permutation(jax.random.PRNGKey(0), n)
    cap = exact_pair_cap(n, 1)
    plans = build_route_plans(perm, 1, cap=cap, may_drop=False)

    fwd_jaxpr = str(jax.make_jaxpr(
        lambda v, pl: plan_shuffle(v, pl, mesh=mesh))(x, plans))
    assert fwd_jaxpr.count("all_to_all") == 1, fwd_jaxpr
    assert fwd_jaxpr.count("sort[") == 0, fwd_jaxpr

    grad_jaxpr = str(jax.make_jaxpr(lambda v, pl: jax.grad(
        lambda u: plan_shuffle(u, pl, mesh=mesh).sum())(v))(x, plans))
    assert grad_jaxpr.count("all_to_all") == 2, grad_jaxpr
    assert grad_jaxpr.count("sort[") == 0, grad_jaxpr

    plan_jaxpr = str(jax.make_jaxpr(
        lambda p: build_route_plans(p, 1, cap=cap, may_drop=False))(perm))
    assert plan_jaxpr.count("sort[") == 0, plan_jaxpr
    assert plan_jaxpr.count("all_to_all") == 0, plan_jaxpr


def test_dense_plan_allocates_no_pos_valid_buffers():
    """The balanced dense path carries ONLY the two gather index maps:
    no position array, no validity mask, no overflow counter, and the
    send buffer has zero slack padding (n_shards * cap == b)."""
    from repro.core.collector_dist import (build_route_plans,
                                           exact_pair_cap,
                                           make_balanced_perm)
    n, s = 64, 4
    perm = make_balanced_perm(jax.random.PRNGKey(0), n, s)
    cap = exact_pair_cap(n, s)
    fwd, bwd = build_route_plans(perm, s, cap=cap, may_drop=False)
    for plan in (fwd, bwd):
        assert plan.dense
        assert plan.overflow is None
        assert s * plan.cap == n // s          # zero slack padding
        leaves = jax.tree_util.tree_leaves(plan)
        assert len(leaves) == 2, leaves        # send_idx + recv_idx only
        # and the plan reproduces the oracle on one shard-slab layout
        x = jax.random.normal(jax.random.PRNGKey(1), (n, 2))
    mesh = jax.make_mesh((1,), ("data",))
    from repro.core.collector_dist import plan_shuffle
    plans1 = build_route_plans(perm, 1, cap=exact_pair_cap(n, 1),
                               may_drop=False)
    out = jax.jit(lambda v, pl: plan_shuffle(v, pl, mesh=mesh))(x, plans1)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(x)[np.asarray(perm)])


def test_exact_pair_cap_matches_deterministic_loads():
    """exact_pair_cap == the measured max pair load of (grouped) balanced
    permutations — the invariant the dense path's drop-freeness rests on."""
    from repro.core.collector_dist import (exact_pair_cap, max_pair_load,
                                           make_balanced_perm,
                                           make_grouped_balanced_perm)
    assert exact_pair_cap(64, 8) == 1
    perm = make_balanced_perm(jax.random.PRNGKey(0), 64, 8)
    assert max_pair_load(perm, 8) == exact_pair_cap(64, 8)
    for rows in ([32, 32], [16, 16, 16, 16], [8] * 8):
        gperm = make_grouped_balanced_perm(jax.random.PRNGKey(1), 64, 8,
                                           rows)
        assert max_pair_load(gperm, 8) <= exact_pair_cap(64, 8, rows)
    # in-slab groups load the full slab on the diagonal
    assert exact_pair_cap(64, 8, [8] * 8) == 8


WORKER_SUBMESH_JAXPR = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re
import jax, jax.numpy as jnp, numpy as np
from repro.core import round as RD
from repro.core.round import streamed_shuffle

mesh = jax.make_mesh((8,), ("data",))
coll = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=0.25,
                            mode="balanced", submesh=True)
n, d = 64, 3
b = n // 8
perm = coll.make_perm(jax.random.PRNGKey(0), n)
prep = coll.prepare(perm, n)
groups = len(coll.group_bounds(n))
assert groups == 4

# every per-group plan pair is DENSE: 2 index leaves, no overflow
# operand, slice-local capacity with zero slack (S * cap == b)
for fwd, bwd in prep.plans:
    for plan in (fwd, bwd):
        assert plan.slice_size == 2, plan.slice_size
        assert plan.dense and plan.overflow is None
        assert not plan.may_drop
        assert plan.slice_size * plan.cap == b, (plan.cap, b)
        assert len(jax.tree_util.tree_leaves(plan)) == 2
print("submesh-dense-plan OK")

x = jnp.zeros((n, d))
fwd_jaxpr = str(jax.make_jaxpr(
    lambda v, pr: streamed_shuffle(coll, pr, n, lambda g: v))(x, prep))
assert fwd_jaxpr.count("all_to_all") == groups, fwd_jaxpr
assert fwd_jaxpr.count("sort[") == 0, fwd_jaxpr
# zero slack padding at every grouped flush: each collective moves the
# per-shard (S=2, cap=4, d) bucket — exactly the b-row slab, no b_g + 1
shapes = re.findall(r"f32\[([\d,]+)\] = all_to_all", fwd_jaxpr)
assert len(shapes) == groups, fwd_jaxpr
for shape in shapes:
    s_, cap_, d_ = map(int, shape.split(","))
    assert (s_, cap_ * s_, d_) == (2, b, d), shape
print("submesh-one-collective OK")

back_jaxpr = str(jax.make_jaxpr(
    lambda v, pr: coll.route_back(v, pr, n))(x, prep))
assert back_jaxpr.count("all_to_all") == groups, back_jaxpr
assert back_jaxpr.count("sort[") == 0, back_jaxpr
print("submesh-route-back OK")
"""


@pytest.mark.parametrize("_", [0])
def test_submesh_stream_is_one_collective_per_group(_, tmp_path):
    """Jaxpr inspection at 8 forced host devices: the sub-mesh streamed
    path emits exactly ONE all_to_all per flush group (and per group on
    the route-back), zero sorts, and every per-group plan is dense —
    2 index leaves, no overflow operand, zero slack padding."""
    out = _run_worker(tmp_path, "worker_submesh_jaxpr.py",
                      WORKER_SUBMESH_JAXPR, 420)
    for token in ("submesh-dense-plan OK", "submesh-one-collective OK",
                  "submesh-route-back OK"):
        assert token in out, out


def test_submesh_plan_builder_is_sortfree():
    """The sub-mesh plan builder needs no mesh: structural checks run
    in-process. Plans are dense at the slice-local exact capacity and the
    builder's jaxpr contains no sort and no collective."""
    from repro.core.collector_dist import (build_submesh_route_plans,
                                           make_balanced_perm)
    n_shards, S, b = 8, 2, 8
    n_g = S * b
    sub = make_balanced_perm(jax.random.PRNGKey(0), n_g, S)
    fwd, bwd = build_submesh_route_plans(sub, 3, n_shards, S)
    for plan in (fwd, bwd):
        assert plan.dense and plan.slice_size == S
        assert plan.overflow is None and not plan.may_drop
        assert plan.cap == b // S                 # exact slice-local cap
        assert plan.send_idx.shape == (n_shards, b)
        assert len(jax.tree_util.tree_leaves(plan)) == 2
    # the embedded rows live exactly at the owning slice [3*S, 4*S)
    send = np.asarray(fwd.send_idx)
    outside = np.ones(n_shards, bool)
    outside[3 * S:4 * S] = False
    assert (send[outside] == 0).all()
    assert (send[~outside] != 0).any()
    jaxpr = str(jax.make_jaxpr(
        lambda p: build_submesh_route_plans(p, 3, n_shards, S))(sub))
    assert jaxpr.count("sort[") == 0, jaxpr
    assert jaxpr.count("all_to_all") == 0, jaxpr


def test_uniform_auto_slack_probing_is_cached():
    """The 16 host-side probe permutations run once per distinct
    (n, shards, groups, probes, seed, margin) key — re-tracing a jitted
    epoch must not repeat them."""
    from repro.core.collector_dist import (_uniform_auto_slack_cached,
                                           uniform_auto_slack)
    _uniform_auto_slack_cached.cache_clear()
    a = uniform_auto_slack(96, 4, [48, 48])
    before = _uniform_auto_slack_cached.cache_info()
    assert before.misses == 1
    b = uniform_auto_slack(96, 4, [48, 48])
    after = _uniform_auto_slack_cached.cache_info()
    assert a == b
    assert after.hits == before.hits + 1
    assert after.misses == before.misses
    # distinct keys still probe
    uniform_auto_slack(96, 4)
    assert _uniform_auto_slack_cached.cache_info().misses == 2


def test_plan_payload_bytes_golden_across_wire_dtypes():
    """Golden wire-byte accounting at the bench cut width (D=512) for all
    three plan families. The numbers are EXACT: 64 exchanged rows cost
    64*512*4 f32 bytes, half that at bf16, and 64*(512+4) at a quantized
    wire (one byte per element plus the 4 bitcast f32-scale lanes packed
    into the payload) — below bf16 and ~0.252x of f32, inside the 0.3x
    budget the quantized exchange is sized against."""
    from repro.core.collector_dist import (build_route_plans,
                                           build_submesh_route_plans,
                                           exact_pair_cap,
                                           make_balanced_perm,
                                           plan_payload_bytes)
    from repro.core.round import StreamingAllToAll
    n, s, d = 64, 8, 512
    perm = make_balanced_perm(jax.random.PRNGKey(0), n, s)
    whole, _ = build_route_plans(perm, s, cap=exact_pair_cap(n, s),
                                 may_drop=False)
    sub = make_balanced_perm(jax.random.PRNGKey(1), 16, 2)
    submesh, _ = build_submesh_route_plans(sub, 3, s, 2)

    class _FakeMesh:
        axis_names = ("data",)
        devices = np.empty((8,), dtype=object)

    coll = StreamingAllToAll(mesh=_FakeMesh(), num_clients=8, alpha=0.5)
    prep = coll.prepare(coll.make_perm(jax.random.PRNGKey(0), n), n)
    grouped = [p for p, _ in prep.plans]

    # every plan family exchanges 64 (padded) rows at this layout, so the
    # golden bytes coincide; what the test pins is the per-dtype row cost
    golden = {None: 131072, "float32": 131072, "bfloat16": 65536,
              "int8": 33024, "float8_e4m3": 33024}
    for plan in [whole, submesh] + grouped:
        for wire, want in golden.items():
            got = plan_payload_bytes(plan, d, 4, wire_dtype=wire)
            assert got == want, (wire, got, want)
    b32 = golden["float32"]
    assert golden["int8"] < golden["bfloat16"]          # beats bf16
    assert golden["int8"] <= 0.3 * b32                  # 0.252x of f32
    assert golden["int8"] == 64 * (d + 4)               # rows + scale lanes
    # per-row accounting scales with the feature width, not the plan
    assert plan_payload_bytes(whole, 16, 4, wire_dtype="int8") == 64 * 20


def test_quantized_exchange_is_one_collective_in_wire_dtype():
    """Jaxpr proof for the quantized path: the int8-wire exchange still
    lowers to exactly ONE all_to_all forward (TWO for forward+backward
    when the backward leg is also quantized), zero sorts, and the
    payload operand itself is in the wire dtype with the packed scale
    lanes as trailing columns — ``i8[S, cap, d+4]``."""
    import re

    from repro.core.collector_dist import (build_route_plans,
                                           exact_pair_cap, plan_shuffle)
    mesh = jax.make_mesh((1,), ("data",))
    n, d = 16, 3
    x = jnp.zeros((n, d))
    perm = jax.random.permutation(jax.random.PRNGKey(0), n)
    plans = build_route_plans(perm, 1, cap=exact_pair_cap(n, 1),
                              may_drop=False)

    fwd_jaxpr = str(jax.make_jaxpr(lambda v, pl: plan_shuffle(
        v, pl, mesh=mesh, wire_dtype="int8"))(x, plans))
    assert fwd_jaxpr.count("all_to_all") == 1, fwd_jaxpr
    assert fwd_jaxpr.count("sort[") == 0, fwd_jaxpr
    ops = re.findall(r"(\w+)\[([\d,]+)\] = all_to_all", fwd_jaxpr)
    assert ops == [("i8", f"1,{n},{d + 4}")], ops

    # quantized fwd + quantized bwd: both payloads in the wire dtype
    grad_jaxpr = str(jax.make_jaxpr(lambda v, pl: jax.grad(
        lambda u: plan_shuffle(u, pl, mesh=mesh, wire_dtype="int8",
                               wire_dtype_bwd="int8").sum())(v))(x, plans))
    assert grad_jaxpr.count("all_to_all") == 2, grad_jaxpr
    assert grad_jaxpr.count("sort[") == 0, grad_jaxpr
    ops = re.findall(r"(\w+)\[([\d,]+)\] = all_to_all", grad_jaxpr)
    assert ops == [("i8", f"1,{n},{d + 4}")] * 2, ops

    # default exact backward: the VJP collective stays f32
    grad_exact = str(jax.make_jaxpr(lambda v, pl: jax.grad(
        lambda u: plan_shuffle(u, pl, mesh=mesh,
                               wire_dtype="int8").sum())(v))(x, plans))
    ops = re.findall(r"(\w+)\[([\d,]+)\] = all_to_all", grad_exact)
    assert ("f32", f"1,{n},{d}") in ops, ops


WORKER_SUBMESH_QUANT_JAXPR = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re
import jax, jax.numpy as jnp, numpy as np
from repro.core import round as RD
from repro.core.round import streamed_shuffle

mesh = jax.make_mesh((8,), ("data",))
coll = RD.StreamingAllToAll(mesh=mesh, num_clients=8, alpha=0.25,
                            mode="balanced", submesh=True,
                            wire_dtype="int8", wire_dtype_bwd="int8")
n, d = 64, 3
b = n // 8
perm = coll.make_perm(jax.random.PRNGKey(0), n)
prep = coll.prepare(perm, n)
groups = len(coll.group_bounds(n))
assert groups == 4

x = jnp.zeros((n, d))
fwd_jaxpr = str(jax.make_jaxpr(
    lambda v, pr: streamed_shuffle(coll, pr, n, lambda g: v))(x, prep))
assert fwd_jaxpr.count("all_to_all") == groups, fwd_jaxpr
assert fwd_jaxpr.count("sort[") == 0, fwd_jaxpr
# one collective per flush group, payload IN the wire dtype with the
# scale lanes packed on: i8 (S=2, cap=4, d+4) — still zero slack rows
ops = re.findall(r"(\w+)\[([\d,]+)\] = all_to_all", fwd_jaxpr)
assert len(ops) == groups, fwd_jaxpr
for dt, shape in ops:
    assert dt == "i8", (dt, shape)
    s_, cap_, d_ = map(int, shape.split(","))
    assert (s_, cap_ * s_, d_) == (2, b, d + 4), shape
print("submesh-quant-one-collective OK")

back_jaxpr = str(jax.make_jaxpr(
    lambda v, pr: coll.route_back(v, pr, n))(x, prep))
assert back_jaxpr.count("all_to_all") == groups, back_jaxpr
assert back_jaxpr.count("sort[") == 0, back_jaxpr
ops = re.findall(r"(\w+)\[([\d,]+)\] = all_to_all", back_jaxpr)
assert len(ops) == groups and all(dt == "i8" for dt, _ in ops), ops
print("submesh-quant-route-back OK")
"""


@pytest.mark.parametrize("_", [0])
def test_submesh_quantized_stream_keeps_collective_structure(_, tmp_path):
    """Jaxpr inspection at 8 forced host devices: the int8-wire sub-mesh
    stream keeps exactly ONE all_to_all per flush group on the forward
    AND the quantized route-back, zero sorts, with the payload operand in
    the wire dtype carrying d+4 columns (rows + packed scale lanes)."""
    out = _run_worker(tmp_path, "worker_submesh_quant_jaxpr.py",
                      WORKER_SUBMESH_QUANT_JAXPR, 420)
    for token in ("submesh-quant-one-collective OK",
                  "submesh-quant-route-back OK"):
        assert token in out, out
