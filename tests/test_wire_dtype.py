"""Quantized wire-format parity: an int8/bf16-wire epoch must track the
f32 DenseTake oracle within a documented per-wire tolerance on EVERY
collector strategy, and the three strategies must agree with EACH OTHER
to f32 parity — per-row quantization is grouping-independent, so the
sync whole-mesh exchange, the streamed per-group exchange, and the
sub-mesh exchange all ship bit-identical quantized rows.

Tolerances (unit-scale smashed rows, measured on the 8-shard synthetic
CIFAR epoch below; the bound is ~5-10x the observed worst case):

  bfloat16 wire : observed max epoch-loss delta ~4e-4  -> bound 5e-3
  int8 wire     : observed max epoch-loss delta ~1.2e-3 -> bound 1.5e-2

int8 gets the looser bound: an 8-bit grid under a per-row amax scale
carries ~0.4% worst-case relative error per element vs bf16's ~0.4%
mantissa rounding WITHOUT the outlier-stretch sensitivity, and the
error compounds through the server backward. The backward leg stays
exact everywhere here (``wire_dtype_bwd=None``), so deltas isolate the
forward smashed-data quantization.

The multi-device matrix runs in a subprocess with 8 forced host devices;
byte accounting and eager validation run in-process.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

WORKER_WIRE_MATRIX = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

TOL = {"bfloat16": 5e-3, "int8": 1.5e-2}   # documented per-wire bounds

V = 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                       V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)
mesh = ED.make_data_mesh(8)
data_sh = ED.shard_client_data(data, mesh)

def fresh():
    return ED.shard_dcml_state(
        jax.tree_util.tree_map(jnp.asarray, st0_host), mesh)

ke = jax.random.PRNGKey(1)
single = jax.jit(lambda k, s, a: E.sfpl_epoch(
    k, s, data, split, opt, opt, num_clients=V, batch_size=8, alpha=a),
    static_argnums=2)

PIPES = (("sync", None), ("double_buffered", None),
         ("double_buffered", True))

for alpha in (0.5, 1.0):
    st_ref = jax.tree_util.tree_map(jnp.asarray, st0_host)
    _, l_ref = single(ke, st_ref, alpha)
    l_ref = np.asarray(l_ref)
    for wire in ("bfloat16", "int8"):
        losses = {}
        for pipe, submesh in PIPES:
            ep = ED.make_sfpl_epoch_sharded(
                split, opt, opt, data_sh, mesh=mesh, num_clients=V,
                batch_size=8, alpha=alpha, collector_mode="balanced",
                collector_pipeline=pipe, collector_submesh=submesh,
                wire_dtype=wire)
            _, l = ep(ke, fresh())
            losses[(pipe, bool(submesh))] = np.asarray(l)
            d = float(np.abs(np.asarray(l) - l_ref).max())
            assert d <= TOL[wire], (alpha, wire, pipe, submesh, d)
            # the quantized run must actually differ from the oracle —
            # a zero delta would mean the wire knob silently fell off
            assert d > 0.0, (alpha, wire, pipe, submesh)
            print(f"wire-parity OK alpha={alpha} wire={wire} "
                  f"pipe={pipe} submesh={bool(submesh)} ({d:.2e})")
        # strategy invariance: same quantized rows regardless of how the
        # exchange is grouped -> f32-level agreement between pipelines
        vals = list(losses.values())
        for other in vals[1:]:
            dd = float(np.abs(vals[0] - other).max())
            assert dd <= 1e-5, (alpha, wire, dd)
        print(f"wire-invariance OK alpha={alpha} wire={wire}")
print("wire-matrix OK")
"""


def _run_worker(tmp_path, name, src, timeout):
    script = tmp_path / name
    script.write_text(src)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


@pytest.mark.parametrize("_", [0])
def test_quantized_epoch_matches_f32_oracle(_, tmp_path):
    """The full differential matrix at 8 forced host devices:
    {MeshAllToAll, StreamingAllToAll, sub-mesh} x alpha {0.5, 1.0} x
    wire {bfloat16, int8} vs the f32 DenseTake oracle, plus
    cross-strategy invariance of the quantized trajectories."""
    out = _run_worker(tmp_path, "worker_wire_matrix.py",
                      WORKER_WIRE_MATRIX, 900)
    for alpha in (0.5, 1.0):
        for wire in ("bfloat16", "int8"):
            assert f"wire-invariance OK alpha={alpha} wire={wire}" in out, out
    assert "wire-matrix OK" in out, out


class _FakeMesh:
    axis_names = ("data",)
    devices = np.empty((8,), dtype=object)


def test_streamed_exchange_bytes_skips_dropped_groups():
    """``StreamingAllToAll.exchange_bytes`` must count ONLY the flush
    groups that are actually exchanged: a group statically skipped under
    full-group dropout issues no collective, so its payload must not be
    billed. With balanced equal-size groups, skipping one of two halves
    the bytes; skipping all yields zero; ``skip=None`` keeps the full
    sum (the pre-dropout behavior)."""
    from repro.core.collector_dist import plan_payload_bytes
    from repro.core.round import StreamingAllToAll
    n, row_elems = 64, 512
    coll = StreamingAllToAll(mesh=_FakeMesh(), num_clients=8, alpha=0.5)
    prep = coll.prepare(coll.make_perm(jax.random.PRNGKey(0), n), n)
    assert len(prep.plans) == 2
    full = coll.exchange_bytes(prep, row_elems, jnp.float32)
    assert full == sum(plan_payload_bytes(p, row_elems, 4)
                       for p, _ in prep.plans)
    assert coll.exchange_bytes(prep, row_elems, jnp.float32,
                               skip=[False, False]) == full
    assert coll.exchange_bytes(prep, row_elems, jnp.float32, None) == full
    assert coll.exchange_bytes(prep, row_elems, jnp.float32,
                               skip=[False, True]) == full // 2
    assert coll.exchange_bytes(prep, row_elems, jnp.float32,
                               skip=[True, True]) == 0
    # and the skip accounting composes with a quantized wire
    qcoll = StreamingAllToAll(mesh=_FakeMesh(), num_clients=8, alpha=0.5,
                              wire_dtype="int8")
    q_full = qcoll.exchange_bytes(prep, row_elems, jnp.float32)
    assert q_full == 2 * qcoll.exchange_bytes(prep, row_elems, jnp.float32,
                                              skip=[True, False])
    assert q_full < full


def test_wire_dtype_names_validated_eagerly():
    """A wire-dtype typo must raise at layout/fit time — before any mesh
    or trace work — for BOTH the forward and backward knobs."""
    from repro.core import engine_dist as ED
    ED.check_sfpl_layout(8, 8, 1, wire_dtype="int8",
                         wire_dtype_bwd="bfloat16")
    with pytest.raises(ValueError, match="unknown wire_dtype 'int4'"):
        ED.check_sfpl_layout(8, 8, 1, wire_dtype="int4")
    with pytest.raises(ValueError, match="unknown wire_dtype 'fp8'"):
        ED.check_sfpl_layout(8, 8, 1, wire_dtype_bwd="fp8")
    with pytest.raises(ValueError, match="unknown wire_dtype"):
        ED.fit_shards(8, 8, wire_dtype="e4m3")
    with pytest.raises(ValueError, match="unknown wire_dtype"):
        ED.fit_shards(8, 8, wire_dtype_bwd="int4")
    # valid names pass straight through the fit search
    assert ED.fit_shards(8, 8, wire_dtype="float8_e4m3",
                         wire_dtype_bwd="int8") >= 1


def test_resolve_wire_noop_cases():
    """``resolve_wire_dtype`` canonicalizes the no-op spellings and the
    collector-side ``_resolve_wire`` refuses to quantize non-float
    payloads (the label permute must ship exact int32 rows)."""
    from repro.core.collector_dist import _resolve_wire
    from repro.core.wire import resolve_wire_dtype
    assert resolve_wire_dtype(None) is None
    assert resolve_wire_dtype("float32") is None
    assert resolve_wire_dtype("int8") == "int8"
    assert _resolve_wire(jnp.dtype(jnp.int32), "int8") is None
    assert _resolve_wire(jnp.dtype(jnp.float32), "int8") == "int8"
    assert _resolve_wire(jnp.dtype(jnp.bfloat16), "bfloat16") is None
