"""Localhost multi-process harness for the pod collector mesh.

``run_multiprocess(fn, num_processes=N, devices_per_process=D)`` spawns N
fresh Python processes on this machine, joins them into ONE JAX
distributed runtime through the production wiring
(``repro.launch.multihost.initialize`` against a coordinator on a free
localhost port, each process's CPU split into D forced XLA devices), runs
the cloudpickled ``fn`` in every process, and returns the per-process
results — so a test can pin a genuinely cross-process sharded epoch
against an oracle and compare what every host saw.

Contract for ``fn``: a zero-argument callable, cloudpickle-serializable
(keep its imports INSIDE the body — by-value pickling then ships no
module state), returning a pickleable value (numpy, not jax arrays). It
runs after ``multihost.initialize``, so ``jax.process_index()`` /
``jax.process_count()`` and ``multihost.make_pod_mesh()`` are live.
Every process must execute the same collective sequence or the runtime
deadlocks — derive all randomness from fixed seeds.

The child sets ``XLA_FLAGS`` / ``JAX_PLATFORMS`` BEFORE importing jax
(the backend reads the forced device count once) and CPU cross-process
collectives run on gloo (``multihost.initialize`` default — the stock
CPU backend cannot run multi-process collectives at all).
"""
import os
import pickle
import socket
import subprocess
import sys
import tempfile
from pathlib import Path

_SRC = str(Path(__file__).resolve().parent.parent / "src")

_CHILD = r"""
import os, pickle, sys
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=%(ndev)d")
os.environ["JAX_PLATFORMS"] = "cpu"
pid = int(sys.argv[1])
from repro.launch import multihost
multihost.initialize("127.0.0.1:%(port)d", num_processes=%(nproc)d,
                     process_id=pid)
with open(%(payload)r, "rb") as f:
    fn = pickle.load(f)
result = fn()
with open(%(outdir)r + "/out-%%d.pkl" %% pid, "wb") as f:
    pickle.dump(result, f)
print("MH-OK", pid, flush=True)
"""


def free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_multiprocess(fn, *, num_processes=2, devices_per_process=4,
                     timeout=1200, strict=True):
    """Run ``fn`` in ``num_processes`` coordinated localhost JAX processes;
    returns ``[fn() result of process 0, ..., of process N-1]``. Raises
    with both processes' combined output on any nonzero exit.

    ``strict=False`` is the fault-injection mode: a process that dies
    (e.g. a ``FaultPlan`` self-SIGKILL) or hangs past ``timeout`` waiting
    on a collective its dead peer will never join is tolerated — its slot
    in the returned list is ``None`` — so a test can observe a crashed
    round and then drive recovery from its checkpoints."""
    import cloudpickle
    # pickle the WHOLE function by value: test modules are importable from
    # the parent's rootdir but not from the child, and by-reference
    # pickling would make the child re-import them (and their jax state)
    mod = sys.modules.get(getattr(fn, "__module__", None))
    if mod is not None and mod.__name__ != "__main__":
        cloudpickle.register_pickle_by_value(mod)
    with tempfile.TemporaryDirectory() as tmp:
        payload = os.path.join(tmp, "fn.pkl")
        with open(payload, "wb") as f:
            f.write(cloudpickle.dumps(fn))
        child = os.path.join(tmp, "child.py")
        with open(child, "w") as f:
            f.write(_CHILD % dict(ndev=devices_per_process,
                                  port=free_port(), nproc=num_processes,
                                  payload=payload, outdir=tmp))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [_SRC] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        procs = [subprocess.Popen(
            [sys.executable, child, str(pid)], env=env, text=True,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
            for pid in range(num_processes)]
        outs = [""] * num_processes
        try:
            for i, p in enumerate(procs):
                try:
                    out, _ = p.communicate(timeout=timeout)
                except subprocess.TimeoutExpired:
                    if strict:
                        raise
                    p.kill()
                    out, _ = p.communicate()
                outs[i] = out
        finally:
            for p in procs:
                if p.poll() is None:
                    p.kill()
        report = "\n".join(f"--- process {i} (exit {p.returncode}) ---\n"
                           f"{out}" for i, (p, out)
                           in enumerate(zip(procs, outs)))
        if strict:
            assert all(p.returncode == 0 for p in procs), report
            assert all(f"MH-OK {i}" in outs[i]
                       for i in range(num_processes)), report
        results = []
        for pid in range(num_processes):
            path = os.path.join(tmp, f"out-{pid}.pkl")
            if strict or os.path.exists(path):
                with open(path, "rb") as f:
                    results.append(pickle.load(f))
            else:
                results.append(None)
        return results
