"""Fault-tolerance building blocks, cheapest-first on one CPU device:

  1. ``core.retry`` — deterministic backoff schedules, the injectable
     clock, and the ``RetryError`` budget contract;
  2. ``core.faults.FaultPlan`` — the schedule is a pure function of
     ``(seed, epoch)`` (any process reconstructs it), both straggler
     policies, the kill schedule, and ``ensure_group_survivor``'s
     revive-don't-crash degradation;
  3. participation validation — ``check_participation`` rejects bad
     shapes and fully-emptied flush groups EAGERLY (naming the group),
     for 1-D and per-step 2-D masks, at the layout/fit entrypoints too;
  4. checkpoint hardening — atomic tmp-then-replace with no tmp litter,
     ValueError (not KeyError/silence) on missing leaves and shape
     mismatches, and the full-train-state roundtrip (params + optimizer +
     BN stats + PRNG key + epoch);
  5. the elastic numerics — mean-over-valid loss rescale and
     valid-weighted BN batch moments against compacted-row references,
     and the DenseTake masked epoch against a surviving-clients oracle at
     1e-5 (the single-device corner of the elastic differential matrix;
     tests/test_elastic.py runs the sharded collectors).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collector as C
from repro.core import engine_dist as ED
from repro.core.faults import FaultPlan, ensure_group_survivor
from repro.core.retry import RetryError, backoff_schedule, retry_call
from repro.checkpoint import npz as CK
from repro.models.common import softmax_cross_entropy
from repro.nn.norm import _batch_moments


# --------------------------------------------------------------------------
# 1. retry/backoff


def test_backoff_schedule_deterministic_and_bounded():
    a = backoff_schedule(6, base_delay=0.5, max_delay=4.0, seed=3)
    b = backoff_schedule(6, base_delay=0.5, max_delay=4.0, seed=3)
    assert a == b and len(a) == 5  # N attempts -> N-1 sleeps
    assert all(d <= 4.0 * 1.5 for d in a)
    assert backoff_schedule(6, base_delay=0.5, max_delay=4.0, seed=4) != a
    # jitter off: pure exponential, capped
    assert backoff_schedule(5, base_delay=1.0, max_delay=4.0,
                            jitter=0.0) == [1.0, 2.0, 4.0, 4.0]
    assert backoff_schedule(1) == []


def test_retry_call_succeeds_after_transient_failures():
    calls, slept = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError(f"transient {len(calls)}")
        return "joined"

    out = retry_call(flaky, attempts=5, base_delay=0.5, max_delay=8.0,
                     seed=1, sleep=slept.append)
    assert out == "joined" and len(calls) == 3
    # it slept the first two delays of the deterministic schedule
    assert slept == backoff_schedule(5, base_delay=0.5, max_delay=8.0,
                                     seed=1)[:2]


def test_retry_call_exhausts_budget():
    slept = []

    def dead():
        raise ConnectionError("coordinator unreachable")

    with pytest.raises(RetryError, match=r"3 attempt\(s\)") as ei:
        retry_call(dead, attempts=3, sleep=slept.append,
                   describe="join test")
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last, ConnectionError)
    assert "join test" in str(ei.value)
    assert len(slept) == 2


def test_retry_call_does_not_catch_unlisted_errors():
    def typed():
        raise ValueError("not transient")

    with pytest.raises(ValueError, match="not transient"):
        retry_call(typed, attempts=5, retry_on=(RuntimeError,),
                   sleep=lambda _: None)


# --------------------------------------------------------------------------
# 2. FaultPlan


def test_fault_plan_is_pure_function_of_seed_and_epoch():
    a = FaultPlan(8, seed=5, drop_rate=0.4, straggler_rate=0.3)
    b = FaultPlan(8, seed=5, drop_rate=0.4, straggler_rate=0.3)
    for ep in range(4):
        np.testing.assert_array_equal(a.available(ep), b.available(ep))
        np.testing.assert_array_equal(a.delays(ep), b.delays(ep))
    c = FaultPlan(8, seed=6, drop_rate=0.4, straggler_rate=0.3)
    assert any(not np.array_equal(a.available(ep), c.available(ep))
               for ep in range(8))
    # epochs decorrelate too
    assert any(not np.array_equal(a.available(0), a.available(ep))
               for ep in range(1, 8))


def test_fault_plan_straggler_policies():
    plan = FaultPlan(8, seed=0, straggler_rate=1.0, straggler_delay=0.25)
    # WAIT policy: everyone participates, host stalls for the slowest
    mask, wait = plan.participation(0, straggler_timeout=None)
    assert mask.all() and wait == 0.25
    # DROP-AND-MASK: universal stragglers all exceed a tighter timeout
    mask, wait = plan.participation(0, straggler_timeout=0.1)
    assert not mask.any() and wait == 0.0
    # a timeout above the delay keeps them (and waits for them)
    mask, wait = plan.participation(0, straggler_timeout=0.5)
    assert mask.all() and wait == 0.25
    # no faults at all: full participation, zero wait
    mask, wait = FaultPlan(8).participation(0)
    assert mask.all() and wait == 0.0


def test_fault_plan_kill_schedule():
    plan = FaultPlan(8, kill_process=1, kill_epoch=2)
    assert plan.should_kill(1, 2)
    assert not plan.should_kill(0, 2)
    assert not plan.should_kill(1, 1)
    assert not FaultPlan(8).should_kill(0, 0)
    # maybe_kill is a no-op off-schedule (it would SIGKILL us otherwise)
    plan.maybe_kill(0, 2)
    plan.maybe_kill(1, 0)


def test_ensure_group_survivor():
    # alpha=0.5 over 8 clients -> flush groups [0..3], [4..7]
    mask, revived = ensure_group_survivor(
        np.array([0, 0, 0, 0, 1, 0, 1, 0], bool), 8, alpha=0.5)
    assert revived == [0]
    np.testing.assert_array_equal(
        mask, np.array([1, 0, 0, 0, 1, 0, 1, 0], bool))
    # untouched when every group already has a survivor
    ok = np.array([0, 1, 0, 0, 0, 0, 0, 1], bool)
    mask, revived = ensure_group_survivor(ok, 8, alpha=0.5)
    assert revived == [] and np.array_equal(mask, ok)
    # all-dead draw: one revival per group
    mask, revived = ensure_group_survivor(np.zeros(8, bool), 8, alpha=0.5)
    assert revived == [0, 4] and mask.sum() == 2
    with pytest.raises(ValueError, match="shape"):
        ensure_group_survivor(np.ones(4, bool), 8)


# --------------------------------------------------------------------------
# 3. participation validation


def test_check_participation_accepts_and_normalizes():
    assert C.check_participation(8, None) is None
    m = C.check_participation(8, [1, 0, 1, 1, 0, 1, 1, 1], alpha=0.5)
    assert m.dtype == bool and m.shape == (8,)
    # per-step 2-D masks validate every row
    m2 = C.check_participation(
        8, np.ones((3, 8), bool), alpha=0.5)
    assert m2.shape == (3, 8)


def test_check_participation_rejects_bad_masks():
    with pytest.raises(ValueError, match=r"\(8,\)"):
        C.check_participation(8, np.ones(4, bool))
    with pytest.raises(ValueError, match="flush group 1"):
        C.check_participation(8, [1, 1, 1, 1, 0, 0, 0, 0], alpha=0.5)
    # 2-D: a later step emptying a group is still caught (named step)
    bad = np.ones((3, 8), bool)
    bad[2, :4] = False
    with pytest.raises(ValueError, match="flush group 0"):
        C.check_participation(8, bad, alpha=0.5)
    # alpha=1.0 is one global group: at least one client must survive
    with pytest.raises(ValueError, match="flush group 0"):
        C.check_participation(4, np.zeros(4, bool))


def test_layout_entrypoints_validate_participation_eagerly():
    bad = np.array([1, 1, 1, 1, 0, 0, 0, 0], bool)
    with pytest.raises(ValueError, match="flush group 1"):
        ED.check_sfpl_layout(8, 8, 8, alpha=0.5, participation=bad)
    # fit_shards must validate up front, NOT swallow the error into its
    # 1-shard fallback
    with pytest.raises(ValueError, match="flush group 1"):
        ED.fit_shards(8, 8, alpha=0.5, participation=bad)
    ok = np.array([1, 0, 0, 0, 0, 0, 0, 1], bool)
    assert ED.fit_shards(8, 8, alpha=0.5, participation=ok) >= 1


def test_participation_row_mask():
    rows = C.participation_row_mask([1, 0, 1], 2)
    np.testing.assert_array_equal(
        np.asarray(rows), [True, True, False, False, True, True])


# --------------------------------------------------------------------------
# 4. checkpoint hardening + full-train-state roundtrip


def test_checkpoint_atomic_no_tmp_litter(tmp_path):
    path = str(tmp_path / "ck.npz")
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    CK.save_checkpoint(path, tree, step=3)
    assert os.path.exists(path)
    assert [f for f in os.listdir(tmp_path)] == ["ck.npz"]  # no tmp files
    out, step = CK.restore_checkpoint(path, tree)
    assert step == 3
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16  # re-cast to the ref dtype


def test_checkpoint_raises_on_mismatch(tmp_path):
    path = str(tmp_path / "ck.npz")
    CK.save_checkpoint(path, {"a": jnp.ones((2, 3))})
    with pytest.raises(ValueError, match="shape"):
        CK.restore_checkpoint(path, {"a": jnp.ones((3, 2))})
    with pytest.raises(ValueError, match="no leaf"):
        CK.restore_checkpoint(path, {"a": jnp.ones((2, 3)),
                                     "zz": jnp.ones(())})


def test_train_state_roundtrip(tmp_path):
    from repro.core import engine as E
    from repro.models import resnet as R
    from repro.optim import sgd_momentum
    cfg = R.ResNetConfig(depth=8, num_classes=4, width=8)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    st = E.init_dcml_state(jax.random.PRNGKey(0),
                           lambda k: R.init(k, cfg), 4, opt, opt)
    key = jax.random.fold_in(jax.random.PRNGKey(1), 7)
    path = str(tmp_path / "state.npz")
    CK.save_train_state(path, st, key=key, epoch=2)
    ref = jax.tree_util.tree_map(jnp.zeros_like, st)
    st2, key2, epoch = CK.restore_train_state(path, ref)
    assert epoch == 2
    np.testing.assert_array_equal(np.asarray(key2), np.asarray(key))
    for a, b in zip(jax.tree_util.tree_leaves(st),
                    jax.tree_util.tree_leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a plain param checkpoint is not a train-state snapshot
    CK.save_checkpoint(str(tmp_path / "p.npz"), {"a": jnp.ones(())})
    with pytest.raises(ValueError, match="no leaf"):
        CK.restore_train_state(str(tmp_path / "p.npz"), {"a": jnp.ones(())})


# --------------------------------------------------------------------------
# 5. elastic numerics


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mean_over_valid_loss_rescale(seed):
    """Masking rows to IGNORE_LABEL == dropping them: the loss means over
    the surviving rows only (the elastic rescale is exact, not 1/N)."""
    rng = np.random.default_rng(seed)
    n, v = 24, 5
    logits = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, size=(n,)), jnp.int32)
    mask = jnp.asarray(rng.random(n) < 0.6)
    if not bool(mask.any()):
        mask = mask.at[0].set(True)
    masked_labels = jnp.where(mask, labels, -100)
    full = softmax_cross_entropy(logits, masked_labels)
    keep = np.where(np.asarray(mask))[0]
    compact = softmax_cross_entropy(logits[keep], labels[keep])
    np.testing.assert_allclose(float(full), float(compact), rtol=1e-6)


@pytest.mark.parametrize("seed", [0, 3])
def test_valid_weighted_bn_moments(seed):
    """_batch_moments with a 0/1 row weight == moments of the compacted
    surviving rows (masked rows contribute exactly zero)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 4, 4, 3)), jnp.float32)
    valid = jnp.asarray(rng.random(16) < 0.5)
    if not bool(valid.any()):
        valid = valid.at[0].set(True)
    axes = (0, 1, 2)
    m, v = _batch_moments(x, axes, valid)
    keep = np.where(np.asarray(valid))[0]
    m_ref, v_ref = _batch_moments(x[keep], axes, None)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                               atol=1e-6)
    # all-valid weight is bit-identical to the unweighted path
    m1, v1 = _batch_moments(x, axes, jnp.ones(16, bool))
    m0, v0 = _batch_moments(x, axes, None)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m0), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v0), atol=1e-6)


def _tiny_problem(num_clients, batch_size):
    from repro.core import engine as E
    from repro.data import make_synthetic_cifar, partition_positive_labels
    from repro.models import resnet as R
    from repro.optim import sgd_momentum
    cfg = R.ResNetConfig(depth=8, num_classes=num_clients, width=8)
    tx, ty, _, _ = make_synthetic_cifar(
        jax.random.PRNGKey(0), num_classes=num_clients,
        train_per_class=2 * batch_size, test_per_class=batch_size, hw=8)
    data = partition_positive_labels(tx, ty, num_clients)
    split = E.make_resnet_split(cfg)
    opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
    init = lambda k: R.init(k, cfg)
    return E, data, split, opt, init


def _tree_maxdiff(a, b):
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def test_dense_take_masked_epoch_matches_surviving_oracle():
    """Single-device elastic corner of the differential matrix: a masked
    epoch == an epoch over only the surviving clients (loss + every state
    leaf at surviving indices), and absent clients' state is FROZEN."""
    V = B = 4
    E, data, split, opt, init = _tiny_problem(V, B)
    mask = np.array([1, 0, 1, 1], bool)   # alpha=0.5 groups [0,1], [2,3]
    surv = np.where(mask)[0]
    st0 = E.init_dcml_state(jax.random.PRNGKey(0), init, V, opt, opt)
    ke = jax.random.PRNGKey(1)

    st_m, l_m = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=B,
        alpha=0.5, participation=jnp.asarray(mask)))(ke, st0)

    # oracle: the SAME problem restricted to the survivors (shared
    # broadcast init makes per-client initial state identical)
    st_o = E.init_dcml_state(jax.random.PRNGKey(0), init, len(surv),
                             opt, opt)
    data_o = {k: v[surv] for k, v in data.items()}
    st_o, l_o = jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data_o, split, opt, opt, num_clients=len(surv),
        batch_size=B, alpha=0.5))(ke, st_o)

    assert _tree_maxdiff(l_m, l_o) < 1e-5
    take = lambda t: jax.tree_util.tree_map(lambda x: x[surv], t)
    for leaf in ("cp", "cbn", "copt"):
        assert _tree_maxdiff(take(st_m[leaf]), st_o[leaf]) < 1e-5, leaf
    for leaf in ("sp", "sbn", "sopt"):
        assert _tree_maxdiff(st_m[leaf], st_o[leaf]) < 1e-5, leaf
    # the absent client's LOCAL state is frozen: BN stats, optimizer
    # momentum, and BN params (excluded from ClientFedServer). Its non-BN
    # params receive the epoch-end broadcast average — that is the global
    # model it downloads on reconnect, already pinned to the oracle above.
    from repro.core.bn_policy import is_bn_path
    st0h = jax.tree_util.tree_map(np.asarray, st0)
    for leaf in ("cbn", "copt"):
        frozen = jax.tree_util.tree_map(lambda x: x[1], st_m[leaf])
        ref = jax.tree_util.tree_map(lambda x: x[1], st0h[leaf])
        assert _tree_maxdiff(frozen, ref) == 0.0, leaf
    moved = jax.tree_util.tree_map_with_path(
        lambda p, a, b: float(np.abs(np.asarray(a)[1] - b[1]).max())
        if is_bn_path(p) else 0.0, st_m["cp"], st0h["cp"])
    assert max(jax.tree_util.tree_leaves(moved)) == 0.0


def test_per_step_mask_matches_per_epoch_mask():
    """A (steps, num_clients) mask with identical rows == the 1-D mask."""
    V = B = 4
    E, data, split, opt, init = _tiny_problem(V, B)  # 2 steps per epoch
    st0 = E.init_dcml_state(jax.random.PRNGKey(0), init, V, opt, opt)
    ke = jax.random.PRNGKey(1)
    mask1 = np.array([1, 1, 0, 1], bool)
    mask2 = np.broadcast_to(mask1, (2, V)).copy()
    run = lambda m: jax.jit(lambda k, s: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=B,
        alpha=0.5, participation=jnp.asarray(m)))(ke, st0)
    st_a, l_a = run(mask1)
    st_b, l_b = run(mask2)
    assert _tree_maxdiff(l_a, l_b) == 0.0
    assert _tree_maxdiff(st_a, st_b) == 0.0


def test_streaming_skip_of_fully_dropped_group_matches_dense():
    """A STATIC mask that empties a whole flush group: the streamed
    collector skips that group's exchange (only reachable via the direct
    round API — the validated entrypoints forbid it) and still matches
    the dense masked collector."""
    from repro.core import round as RD
    V = B = 4
    E, data, split, opt, init = _tiny_problem(V, B)
    mask = np.array([0, 0, 1, 1], bool)   # group 0 of alpha=0.5 is empty
    st0 = E.init_dcml_state(jax.random.PRNGKey(0), init, V, opt, opt)
    ke = jax.random.PRNGKey(1)

    st_d, l_d = jax.jit(lambda k, s: RD.sfpl_round(
        k, s, data, split, opt, opt, num_clients=V, batch_size=B,
        collector=RD.SINGLE.collector(V, alpha=0.5),
        participation=mask))(ke, st0)

    mesh = ED.make_data_mesh(1)
    coll = RD.StreamingAllToAll(mesh=mesh, num_clients=V, axis="data",
                                alpha=0.5)
    st_s, l_s = jax.jit(lambda k, s: RD.sfpl_round(
        k, s, data, split, opt, opt, num_clients=V, batch_size=B,
        collector=coll, participation=mask))(ke, st0)

    assert _tree_maxdiff(l_s, l_d) < 1e-5
    assert _tree_maxdiff(st_s, st_d) < 1e-5
