"""Mesh-sharded SFPL engine: numerical interchangeability with the
single-device engine under 8 forced host devices (subprocess, since the
device count must be fixed before jax initializes)."""
import os
import subprocess
import sys

import numpy as np
import pytest

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

V = 8                       # clients == classes, one client per shard
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)

st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)

# single-device reference trajectory
ref_step = jax.jit(lambda k, s: E.sfpl_epoch(
    k, s, data, split, opt, opt, num_clients=V, batch_size=8,
    bn_mode="cmsd"))
st = st0
key = jax.random.PRNGKey(1)
epoch_keys, ref_losses = [], []
for _ in range(2):
    key, ke = jax.random.split(key)
    epoch_keys.append(ke)
    st, l = ref_step(ke, st)
    ref_losses.append(np.asarray(l))
ref = np.concatenate(ref_losses)

# sharded engine, same seed: the collector swaps the uniform pool shuffle
# for balanced all_to_all blocks; SFPL's server update is
# permutation-invariant, so trajectories must agree to float tolerance.
mesh = ED.make_data_mesh(8)
data_sh = ED.shard_client_data(data, mesh)

def fresh_state():
    st = jax.tree_util.tree_map(jnp.asarray, st0_host)
    return ED.shard_dcml_state(st, mesh)

epoch = ED.make_sfpl_epoch_sharded(split, opt, opt, data_sh, mesh=mesh,
                                   num_clients=V, batch_size=8,
                                   check_capacity=True)
st = fresh_state()
sh_losses = []
for ke in epoch_keys:
    st, l = epoch(ke, st)      # donated carry: hot buffers reused in place
    sh_losses.append(np.asarray(l))
sh = np.concatenate(sh_losses)
diff = float(np.abs(ref - sh).max())
assert diff < 1e-4, (diff, ref, sh)
print(f"trajectory-parity OK ({diff:.2e})")

# FedAvg'd client params must match too (all-reduce over the sharded axis)
st_ref = st0
for ke in epoch_keys:
    st_ref, _ = ref_step(ke, st_ref)
for a, b in zip(jax.tree_util.tree_leaves(st_ref["cp"]),
                jax.tree_util.tree_leaves(st["cp"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("params-parity OK")

# Pallas kernel on the local bucket permute: identical losses
epoch_k = ED.make_sfpl_epoch_sharded(split, opt, opt, data_sh, mesh=mesh,
                                     num_clients=V, batch_size=8,
                                     use_kernel=True)
stk, lk = epoch_k(epoch_keys[0], fresh_state())
dk = float(np.abs(np.asarray(lk) - ref_losses[0]).max())
assert dk < 1e-4, dk
print(f"kernel-parity OK ({dk:.2e})")
"""

WORKER_SCHEMES = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

V = 8
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)
st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)
mesh = ED.make_data_mesh(8)
data_sh = ED.shard_client_data(data, mesh)

def fresh_dense():
    return jax.tree_util.tree_map(jnp.asarray, st0_host)

def fresh_sharded():
    return ED.shard_dcml_state(fresh_dense(), mesh)

ke = jax.random.split(jax.random.PRNGKey(1))[1]

# alpha<1: per-flush-group balanced exchanges on the mesh must track the
# single-device flush-group shuffle (the SFPL server update is
# permutation-invariant within the pool)
for alpha in (0.25, 0.5):
    dense = jax.jit(lambda k, s, a=alpha: E.sfpl_epoch(
        k, s, data, split, opt, opt, num_clients=V, batch_size=8, alpha=a))
    _, l_d = dense(ke, fresh_dense())
    epoch = ED.make_sfpl_epoch_sharded(split, opt, opt, data_sh, mesh=mesh,
                                       num_clients=V, batch_size=8,
                                       alpha=alpha, check_capacity=True)
    _, l_s = epoch(ke, fresh_sharded())
    d = float(np.abs(np.asarray(l_d) - np.asarray(l_s)).max())
    assert d < 1e-4, (alpha, d)
print("alpha-parity OK")

# paper-faithful uniform collector mode with auto-sized slack
dense1 = jax.jit(lambda k, s: E.sfpl_epoch(
    k, s, data, split, opt, opt, num_clients=V, batch_size=8))
_, l_ref = dense1(ke, fresh_dense())
epoch_u = ED.make_sfpl_epoch_sharded(split, opt, opt, data_sh, mesh=mesh,
                                     num_clients=V, batch_size=8,
                                     collector_mode="uniform")
_, l_u = epoch_u(ke, fresh_sharded())
du = float(np.abs(np.asarray(l_ref) - np.asarray(l_u)).max())
assert du < 1e-4, du
print("uniform-parity OK")

# sharded SFLv2: server stream sharded over the batch axis, sequential
# client visitation (the catastrophic-forgetting order) preserved
sfl = jax.jit(lambda k, s: E.sflv2_epoch(
    k, s, data, split, opt, opt, num_clients=V, batch_size=8))
sfl_sh = ED.make_sflv2_epoch_sharded(split, opt, opt, data, mesh=mesh,
                                     num_clients=V, batch_size=8)
st_d, st_s = fresh_dense(), fresh_dense()
ds = []
for ke2 in jax.random.split(jax.random.PRNGKey(2), 2):
    st_d, l_d = sfl(ke2, st_d)
    st_s, l_s = sfl_sh(ke2, st_s)
    ds.append(float(np.abs(np.asarray(l_d) - np.asarray(l_s)).max()))
assert max(ds) < 1e-4, ds
for a, b in zip(jax.tree_util.tree_leaves(st_d["sp"]),
                jax.tree_util.tree_leaves(st_s["sp"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("sflv2-parity OK")
"""


@pytest.mark.parametrize("_", [0])
def test_sharded_engine_matches_single_device(_, tmp_path):
    script = tmp_path / "worker.py"
    script.write_text(WORKER)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    for token in ("trajectory-parity OK", "params-parity OK",
                  "kernel-parity OK"):
        assert token in res.stdout, res.stdout


@pytest.mark.parametrize("_", [0])
def test_sharded_schemes_match_single_device(_, tmp_path):
    """alpha<1 flush groups, the uniform collector mode, and sharded SFLv2
    all track their single-device counterparts at 8 forced host devices."""
    script = tmp_path / "worker_schemes.py"
    script.write_text(WORKER_SCHEMES)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    for token in ("alpha-parity OK", "uniform-parity OK",
                  "sflv2-parity OK"):
        assert token in res.stdout, res.stdout


class _FakeMesh:
    """Enough mesh surface for the eager validators (axis_names + device
    grid shape), usable in the single-device pytest process."""
    axis_names = ("data",)
    devices = np.empty((8,), dtype=object)


def test_sharded_engine_layout_validation():
    """Unshardable layouts are rejected eagerly (ValueError before any
    device work): flush groups must cover whole shard slabs, and the SFLv2
    batch axis must divide over the mesh."""
    import jax
    import jax.numpy as jnp
    from repro.core import engine_dist as ED
    mesh = _FakeMesh()
    data = {"x": jnp.zeros((4, 8, 2)), "y": jnp.zeros((4, 8), jnp.int32)}
    with pytest.raises(ValueError, match="divide evenly"):
        ED.sfpl_epoch_sharded(
            jax.random.PRNGKey(0), {}, data, None, None, None, mesh=mesh,
            num_clients=4, batch_size=8)
    # N=16 over 8 shards -> 8-row slabs; alpha=0.2 makes 3-client (12-row)
    # flush groups that straddle slab boundaries
    with pytest.raises(ValueError, match="flush group"):
        ED.sfpl_epoch_sharded(
            jax.random.PRNGKey(0), {}, data, None, None, None, mesh=mesh,
            num_clients=16, batch_size=4, alpha=0.2)
    # aligned 4-shard groups, but the 3-row slab cannot split into 4 blocks
    with pytest.raises(ValueError, match="balanced exchange"):
        ED.sfpl_epoch_sharded(
            jax.random.PRNGKey(0), {}, data, None, None, None, mesh=mesh,
            num_clients=8, batch_size=3, alpha=0.5)
    with pytest.raises(ValueError, match="batch_size"):
        ED.sflv2_epoch_sharded(
            jax.random.PRNGKey(0), {}, data, None, None, None, mesh=mesh,
            num_clients=8, batch_size=12)


def test_check_sfpl_layout_accepts_aligned_groups():
    """The acceptance layout (8 clients, 8 shards, B=8) validates for one
    global flush and for alpha in {0.25, 0.5} grouped flushes."""
    from repro.core.engine_dist import check_sfpl_layout
    assert check_sfpl_layout(8, 8, 8) == [64]
    assert check_sfpl_layout(8, 8, 8, alpha=0.5) == [32, 32]
    assert check_sfpl_layout(8, 8, 8, alpha=0.25) == [16, 16, 16, 16]
    assert check_sfpl_layout(8, 8, 8, alpha=0.25,
                             collector_mode="uniform") == [16] * 4
    # groups living inside one slab need no exchange and are accepted
    assert check_sfpl_layout(8, 8, 2, alpha=0.25) == [16] * 4
    # uniform mode has no alignment requirement (slack is probed)
    assert check_sfpl_layout(16, 4, 8, alpha=0.2,
                             collector_mode="uniform") == [12] * 5 + [4]
