"""Mesh-sharded SFPL engine: numerical interchangeability with the
single-device engine under 8 forced host devices (subprocess, since the
device count must be fixed before jax initializes)."""
import os
import subprocess
import sys

import pytest

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import engine as E
from repro.core import engine_dist as ED
from repro.data import make_synthetic_cifar, partition_positive_labels
from repro.models import resnet as R
from repro.optim import sgd_momentum

V = 8                       # clients == classes, one client per shard
cfg = R.ResNetConfig(depth=8, num_classes=V, width=8)
key = jax.random.PRNGKey(0)
tx, ty, ex, ey = make_synthetic_cifar(key, num_classes=V,
                                      train_per_class=16, test_per_class=8,
                                      hw=8)
data = partition_positive_labels(tx, ty, V)
split = E.make_resnet_split(cfg)
opt = sgd_momentum(0.05, momentum=0.9, weight_decay=5e-4)

st0 = E.init_dcml_state(jax.random.PRNGKey(0), lambda k: R.init(k, cfg),
                        V, opt, opt)
st0_host = jax.tree_util.tree_map(np.asarray, st0)

# single-device reference trajectory
ref_step = jax.jit(lambda k, s: E.sfpl_epoch(
    k, s, data, split, opt, opt, num_clients=V, batch_size=8,
    bn_mode="cmsd"))
st = st0
key = jax.random.PRNGKey(1)
epoch_keys, ref_losses = [], []
for _ in range(2):
    key, ke = jax.random.split(key)
    epoch_keys.append(ke)
    st, l = ref_step(ke, st)
    ref_losses.append(np.asarray(l))
ref = np.concatenate(ref_losses)

# sharded engine, same seed: the collector swaps the uniform pool shuffle
# for balanced all_to_all blocks; SFPL's server update is
# permutation-invariant, so trajectories must agree to float tolerance.
mesh = ED.make_data_mesh(8)
data_sh = ED.shard_client_data(data, mesh)

def fresh_state():
    st = jax.tree_util.tree_map(jnp.asarray, st0_host)
    return ED.shard_dcml_state(st, mesh)

epoch = ED.make_sfpl_epoch_sharded(split, opt, opt, data_sh, mesh=mesh,
                                   num_clients=V, batch_size=8,
                                   check_capacity=True)
st = fresh_state()
sh_losses = []
for ke in epoch_keys:
    st, l = epoch(ke, st)      # donated carry: hot buffers reused in place
    sh_losses.append(np.asarray(l))
sh = np.concatenate(sh_losses)
diff = float(np.abs(ref - sh).max())
assert diff < 1e-4, (diff, ref, sh)
print(f"trajectory-parity OK ({diff:.2e})")

# FedAvg'd client params must match too (all-reduce over the sharded axis)
st_ref = st0
for ke in epoch_keys:
    st_ref, _ = ref_step(ke, st_ref)
for a, b in zip(jax.tree_util.tree_leaves(st_ref["cp"]),
                jax.tree_util.tree_leaves(st["cp"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("params-parity OK")

# Pallas kernel on the local bucket permute: identical losses
epoch_k = ED.make_sfpl_epoch_sharded(split, opt, opt, data_sh, mesh=mesh,
                                     num_clients=V, batch_size=8,
                                     use_kernel=True)
stk, lk = epoch_k(epoch_keys[0], fresh_state())
dk = float(np.abs(np.asarray(lk) - ref_losses[0]).max())
assert dk < 1e-4, dk
print(f"kernel-parity OK ({dk:.2e})")

# alpha<1 is explicitly unsupported on the sharded path
try:
    ED.sfpl_epoch_sharded(epoch_keys[0], fresh_state(), data_sh, split,
                          opt, opt, mesh=mesh, num_clients=V, batch_size=8,
                          alpha=0.5)
    raise SystemExit("alpha<1 should raise")
except NotImplementedError:
    print("alpha-guard OK")
"""


@pytest.mark.parametrize("_", [0])
def test_sharded_engine_matches_single_device(_, tmp_path):
    script = tmp_path / "worker.py"
    script.write_text(WORKER)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    for token in ("trajectory-parity OK", "params-parity OK",
                  "kernel-parity OK", "alpha-guard OK"):
        assert token in res.stdout, res.stdout


def test_sharded_engine_alpha_guard():
    """alpha<1 (partial collector flushes) is rejected eagerly, before any
    device work."""
    import jax
    import jax.numpy as jnp
    from repro.core import engine_dist as ED
    mesh = ED.make_data_mesh(1)
    with pytest.raises(NotImplementedError, match="alpha"):
        ED.sfpl_epoch_sharded(
            jax.random.PRNGKey(0), {}, {"x": jnp.zeros((4, 8, 2)),
                                        "y": jnp.zeros((4, 8), jnp.int32)},
            None, None, None, mesh=mesh, num_clients=4, batch_size=8,
            alpha=0.5)
