#!/usr/bin/env python
"""Offline markdown link checker for the docs tree.

Validates every ``[text](target)`` in the given markdown files:

  * relative file targets must exist (checked against the *linking*
    file's directory; ``#fragment`` suffixes are checked against the
    target file's headings, GitHub anchor style);
  * bare ``#fragment`` targets must match a heading in the same file;
  * ``http(s)://`` / ``mailto:`` targets are accepted on syntax alone —
    CI runs offline, so external reachability is out of scope.

Usage: python tools/check_links.py README.md docs/*.md
Exits 1 listing every broken link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_anchor(heading):
    """GitHub's heading -> anchor slug (lowercase, spaces to dashes,
    punctuation dropped)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[`*_]", "", slug)
    slug = re.sub(r"[^\w\- ]", "", slug)
    return re.sub(r" +", "-", slug)


def anchors_of(path):
    text = path.read_text(encoding="utf-8")
    text = CODE_FENCE_RE.sub("", text)
    return {github_anchor(h) for h in HEADING_RE.findall(text)}


def check_file(md, errors):
    text = md.read_text(encoding="utf-8")
    text = CODE_FENCE_RE.sub("", text)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if path_part and not dest.exists():
            errors.append(f"{md}: broken link -> {target} "
                          f"(no such file {dest})")
            continue
        if frag and dest.suffix == ".md":
            if github_anchor(frag) not in anchors_of(dest):
                errors.append(f"{md}: broken anchor -> {target} "
                              f"(no heading #{frag} in {dest.name})")


def main(argv):
    files = [Path(a) for a in argv] or [Path("README.md")]
    missing = [f for f in files if not f.exists()]
    if missing:
        print(f"no such file(s): {missing}", file=sys.stderr)
        return 1
    errors = []
    for md in files:
        check_file(md, errors)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} file(s): "
          f"{'FAIL' if errors else 'all links OK'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
